//! Sequential-vs-parallel engine equivalence (DESIGN.md Sections 4 and
//! 10) and the wall-clock scaling checks.
//!
//! The contract under test: `ExecutionMode::Parallel(n)` must produce
//! **bit-identical** output to `ExecutionMode::Sequential` — same depths,
//! same parent tree (not just a valid one), same per-level frontier
//! census, directions, per-PE work counters, and communication stats —
//! for any graph, partitioning, thread count, and root, *with the
//! intra-partition kernel chunking of Section 10 engaged* (every
//! `Parallel(n)` run splits each CPU kernel into up to `n` chunks). Plus
//! two load-tolerant scale-18 RMAT speedup checks: 4 worker threads must
//! beat 1 both with balanced random placement and with the specialized
//! hub partitioning, where all edge work concentrates in one partition
//! and only the nested chunking can parallelize it.
//!
//! The CI matrix exports `TOTEM_DO_TEST_THREADS`: `1` pins fully
//! sequential in-test graph construction, while values above 1
//! parallelize the builds and join the equivalence thread ladders — so
//! the two legs exercise genuinely different schedules of the same
//! bit-identical pipeline.

use totem_do::bfs::{validate_graph500, BfsRun, HybridConfig, HybridRunner, PolicyKind};
use totem_do::engine::{ExecutionMode, SimAccelerator};
use totem_do::graph::generator::{kronecker, kronecker_par, GeneratorConfig, RealWorldClass};
use totem_do::graph::{build_csr, build_csr_par, Csr, EdgeList};
use totem_do::partition::{
    random_partition, specialized_partition, HardwareConfig, LayoutOptions, PartitionedGraph,
};
use totem_do::util::proptest_lite::{gen, run_cases};
use totem_do::util::Xoshiro256;

fn hw(s: usize, g: usize) -> HardwareConfig {
    HardwareConfig { cpu_sockets: s, gpus: g, gpu_mem_bytes: 1 << 24, gpu_max_degree: 32 }
}

/// Thread budget injected by the CI matrix (`TOTEM_DO_TEST_THREADS`).
/// `1` pins fully sequential in-test graph construction (the other half
/// of the determinism story — Section 9); values above 1 parallelize the
/// builds AND join the equivalence thread ladders.
fn ci_threads() -> Option<usize> {
    std::env::var("TOTEM_DO_TEST_THREADS").ok()?.parse().ok()
}

/// The standard tested thread ladder plus the CI matrix value (when > 1;
/// sequential is always the baseline every ladder entry compares against).
fn thread_ladder() -> Vec<usize> {
    let mut ts = vec![2, 4, 8];
    if let Some(t) = ci_threads().filter(|&n| n > 1) {
        if !ts.contains(&t) {
            ts.push(t);
        }
    }
    ts
}

/// Worker threads for in-test graph construction — the CI matrix value
/// (bit-identical output at any count by the Section 9 contract),
/// defaulting to 4 for wall-clock.
fn build_threads() -> usize {
    ci_threads().unwrap_or(4).max(1)
}

fn run_on(pg: &PartitionedGraph, policy: PolicyKind, exec: ExecutionMode, root: u32) -> BfsRun {
    let has_gpu = pg.parts.iter().any(|p| p.kind.is_gpu());
    let mut sim = SimAccelerator::new(pg.parts.len(), pg.num_vertices);
    let accel = if has_gpu { Some(&mut sim) } else { None };
    let cfg = HybridConfig { policy, exec, ..Default::default() };
    let mut runner = HybridRunner::new(pg, cfg, accel).unwrap();
    runner.run(root).unwrap()
}

/// Full bitwise equivalence: results AND instrumentation.
fn assert_equivalent(g: &Csr, seq: &BfsRun, par: &BfsRun, root: u32, what: &str) {
    assert_eq!(seq.depth, par.depth, "{what}: level assignments diverge");
    assert_eq!(seq.parent, par.parent, "{what}: parent trees diverge");
    assert_eq!(seq.levels, par.levels, "{what}: per-level stats diverge");
    assert_eq!(seq.reached_vertices, par.reached_vertices, "{what}");
    assert_eq!(seq.reached_edge_endpoints, par.reached_edge_endpoints, "{what}");
    assert_eq!(seq.init_bytes, par.init_bytes, "{what}");
    assert_eq!(seq.aggregation_bytes, par.aggregation_bytes, "{what}");
    validate_graph500(g, root, &par.parent, &par.depth)
        .unwrap_or_else(|e| panic!("{what}: parallel run fails Graph500 validation: {e}"));
}

#[test]
fn rmat_parallel_matches_sequential_across_configs_and_thread_counts() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(11, 21)));
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    for (s, gp) in [(2, 0), (3, 0), (2, 2), (1, 3)] {
        let (pg, _) = specialized_partition(&g, &hw(s, gp), &LayoutOptions::paper());
        let seq = run_on(&pg, PolicyKind::direction_optimized(), ExecutionMode::Sequential, root);
        for threads in thread_ladder() {
            let par = run_on(
                &pg,
                PolicyKind::direction_optimized(),
                ExecutionMode::Parallel(threads),
                root,
            );
            assert_equivalent(&g, &seq, &par, root, &format!("{s}S{gp}G x{threads}"));
        }
    }
}

#[test]
fn realworld_shaped_graphs_parallel_matches_sequential() {
    // The paper's crawl classes at test scale (full class sizes are
    // bench-sized); their skew exercises hub-heavy partitions — exactly
    // where the intra-partition chunking concentrates.
    for class in [
        RealWorldClass::TwitterSim,
        RealWorldClass::WikipediaSim,
        RealWorldClass::LiveJournalSim,
    ] {
        let mut cfg = class.config(31);
        cfg.scale = 11;
        let g = build_csr(&kronecker(&cfg));
        let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let (pg, _) = specialized_partition(&g, &hw(2, 2), &LayoutOptions::paper());
        let seq = run_on(&pg, PolicyKind::direction_optimized(), ExecutionMode::Sequential, root);
        for threads in thread_ladder() {
            let par = run_on(
                &pg,
                PolicyKind::direction_optimized(),
                ExecutionMode::Parallel(threads),
                root,
            );
            assert_equivalent(&g, &seq, &par, root, &format!("{} x{threads}", class.name()));
        }
    }
}

#[test]
fn parent_tie_breaks_across_chunks_match_sequential() {
    // Regression for the chunk-order merge rule: a wide frontier (past
    // the driver's parallel-kernel gate) where every frontier vertex
    // points at the same few targets, so nearly every activation is a
    // parent tie between chunks. The winner must be the sequential one —
    // the first reaching edge in whole-queue order (lowest chunk wins) —
    // at every thread count.
    let spokes = 200u32; // > the 128-vertex parallel-kernel gate
    let shared = 10u32;
    let mut edges: Vec<(u32, u32)> = (1..=spokes).map(|v| (0, v)).collect();
    for v in 1..=spokes {
        for t in 0..shared {
            edges.push((v, spokes + 1 + t));
        }
    }
    let g = build_csr(&EdgeList { num_vertices: (spokes + shared + 1) as usize, edges });
    for (s, gp) in [(2, 0), (3, 1)] {
        let (pg, _) = specialized_partition(&g, &hw(s, gp), &LayoutOptions::paper());
        let seq = run_on(&pg, PolicyKind::AlwaysTopDown, ExecutionMode::Sequential, 0);
        for threads in thread_ladder() {
            let par = run_on(&pg, PolicyKind::AlwaysTopDown, ExecutionMode::Parallel(threads), 0);
            assert_equivalent(&g, &seq, &par, 0, &format!("tie-break {s}S{gp}G x{threads}"));
        }
    }
}

#[test]
fn prop_parallel_equivalence_on_random_graphs() {
    // Random graphs x random hardware shapes x random thread counts x
    // random roots, both policies.
    run_cases(40, 0x9A11, |rng: &mut Xoshiro256| {
        let el = gen::edge_list(rng, 140, 600);
        let g = build_csr(&el);
        let cfg_hw = HardwareConfig {
            cpu_sockets: gen::int_in(rng, 1, 4),
            gpus: gen::int_in(rng, 0, 2),
            gpu_mem_bytes: 1 << 22,
            gpu_max_degree: 32,
        };
        let (pg, _) = specialized_partition(&g, &cfg_hw, &LayoutOptions::paper());
        let policy = if rng.next_below(2) == 0 {
            PolicyKind::direction_optimized()
        } else {
            PolicyKind::AlwaysTopDown
        };
        let threads = gen::int_in(rng, 2, 8);
        let root = rng.next_below(g.num_vertices as u64) as u32;
        let seq = run_on(&pg, policy, ExecutionMode::Sequential, root);
        let par = run_on(&pg, policy, ExecutionMode::Parallel(threads), root);
        assert_equivalent(&g, &seq, &par, root, &format!("random x{threads}"));
    });
}

/// Load-tolerant speedup protocol shared by the scale-18 checks: warm up
/// both runners (page-in, buffer allocation), interleave timed reps so
/// background load drifts affect both modes equally, take best-of over up
/// to 3 rounds with early exit (retries absorb transient CI noise without
/// weakening the assertion), assert bitwise equivalence, then assert the
/// speedup — unless the host is oversubscribed (fewer cores than worker
/// threads), where it reports and skips: the assertion is about the
/// engine, not about a contended 2-vCPU runner.
fn assert_parallel_speedup(
    g: &Csr,
    pg: &PartitionedGraph,
    root: u32,
    threads: usize,
    reps: usize,
    what: &str,
) {
    let mk_runner = |exec: ExecutionMode| {
        let cfg =
            HybridConfig { policy: PolicyKind::direction_optimized(), exec, ..Default::default() };
        HybridRunner::<SimAccelerator>::new(pg, cfg, None).unwrap()
    };
    let mut seq_runner = mk_runner(ExecutionMode::Sequential);
    let mut par_runner = mk_runner(ExecutionMode::Parallel(threads));

    seq_runner.run(root).unwrap();
    par_runner.run(root).unwrap();
    let mut seq_best = f64::INFINITY;
    let mut par_best = f64::INFINITY;
    let mut seq_run = None;
    let mut par_run = None;
    for round in 0..3 {
        for _ in 0..reps {
            let s = seq_runner.run(root).unwrap();
            seq_best = seq_best.min(s.wall.as_secs_f64());
            seq_run = Some(s);
            let p = par_runner.run(root).unwrap();
            par_best = par_best.min(p.wall.as_secs_f64());
            par_run = Some(p);
        }
        if par_best < seq_best {
            break;
        }
        eprintln!(
            "round {round}: no speedup yet ({what}: seq {seq_best:.4}s, par {par_best:.4}s); \
             retrying"
        );
    }
    let (seq_run, par_run) = (seq_run.unwrap(), par_run.unwrap());
    assert_equivalent(g, &seq_run, &par_run, root, what);

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    eprintln!(
        "{what}: sequential best {:.1} ms, {threads}-thread best {:.1} ms ({cores} cores, {:.2}x)",
        seq_best * 1e3,
        par_best * 1e3,
        seq_best / par_best
    );
    if cores < threads && par_best >= seq_best {
        eprintln!(
            "SKIP speedup assertion ({what}): only {cores} cores for {threads} worker threads \
             (oversubscribed host; equivalence above still verified)"
        );
        return;
    }
    assert!(
        par_best < seq_best,
        "{what}: {threads} worker threads ({par_best:.4}s) must beat sequential \
         ({seq_best:.4}s) on {cores} cores"
    );
}

#[test]
fn scale18_rmat_parallel_is_faster_than_sequential() {
    // Acceptance check: a scale-18 RMAT BFS through the hybrid engine is
    // measurably faster wall-clock with 4 worker threads than with 1.
    // Partition over 4 CPU sockets (random placement balances edge work).
    // The graph build honours the CI matrix budget (same bytes either way).
    let bt = build_threads();
    let g = build_csr_par(&kronecker_par(&GeneratorConfig::graph500(18, 42), bt), bt);
    let pg = random_partition(&g, &hw(4, 0), &LayoutOptions::paper(), 7);
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    assert_parallel_speedup(&g, &pg, root, 4, 3, "scale18 x4");
}

#[test]
fn scale18_hub_partition_parallel_is_faster_than_sequential() {
    // Acceptance check for the *nested* parallelism: a single CPU
    // partition owns the hubs and every edge — the extreme of the
    // specialized placement's skew, and exactly the shape where the PR 1
    // one-thread-per-partition scheme had nothing to parallelize
    // (Amdahl-bound on the one hot kernel). Any speedup here can only
    // come from intra-partition chunking.
    let bt = build_threads();
    let g = build_csr_par(&kronecker_par(&GeneratorConfig::graph500(18, 42), bt), bt);
    let (pg, _) = specialized_partition(&g, &hw(1, 0), &LayoutOptions::paper());
    assert_eq!(pg.parts.len(), 1, "precondition: one hot partition holds all edge work");
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    assert_parallel_speedup(&g, &pg, root, 4, 2, "scale18 hub x4");
}
