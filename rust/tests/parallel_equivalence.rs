//! Sequential-vs-parallel engine equivalence (DESIGN.md Section 4) and the
//! wall-clock scaling check.
//!
//! The contract under test: `ExecutionMode::Parallel(n)` must produce
//! **bit-identical** output to `ExecutionMode::Sequential` — same depths,
//! same parent tree (not just a valid one), same per-level frontier
//! census, directions, per-PE work counters, and communication stats —
//! for any graph, partitioning, thread count, and root. Plus: on a
//! scale-18 RMAT graph, 4 worker threads must beat 1 in wall-clock.

use totem_do::bfs::{validate_graph500, BfsRun, HybridConfig, HybridRunner, PolicyKind};
use totem_do::engine::{ExecutionMode, SimAccelerator};
use totem_do::graph::generator::{kronecker, GeneratorConfig, RealWorldClass};
use totem_do::graph::{build_csr, Csr};
use totem_do::partition::{
    random_partition, specialized_partition, HardwareConfig, LayoutOptions, PartitionedGraph,
};
use totem_do::util::proptest_lite::{gen, run_cases};
use totem_do::util::Xoshiro256;

fn hw(s: usize, g: usize) -> HardwareConfig {
    HardwareConfig { cpu_sockets: s, gpus: g, gpu_mem_bytes: 1 << 24, gpu_max_degree: 32 }
}

fn run_on(pg: &PartitionedGraph, policy: PolicyKind, exec: ExecutionMode, root: u32) -> BfsRun {
    let has_gpu = pg.parts.iter().any(|p| p.kind.is_gpu());
    let mut sim = SimAccelerator::new(pg.parts.len(), pg.num_vertices);
    let accel = if has_gpu { Some(&mut sim) } else { None };
    let cfg = HybridConfig { policy, exec, ..Default::default() };
    let mut runner = HybridRunner::new(pg, cfg, accel).unwrap();
    runner.run(root).unwrap()
}

/// Full bitwise equivalence: results AND instrumentation.
fn assert_equivalent(g: &Csr, seq: &BfsRun, par: &BfsRun, root: u32, what: &str) {
    assert_eq!(seq.depth, par.depth, "{what}: level assignments diverge");
    assert_eq!(seq.parent, par.parent, "{what}: parent trees diverge");
    assert_eq!(seq.levels, par.levels, "{what}: per-level stats diverge");
    assert_eq!(seq.reached_vertices, par.reached_vertices, "{what}");
    assert_eq!(seq.reached_edge_endpoints, par.reached_edge_endpoints, "{what}");
    assert_eq!(seq.init_bytes, par.init_bytes, "{what}");
    assert_eq!(seq.aggregation_bytes, par.aggregation_bytes, "{what}");
    validate_graph500(g, root, &par.parent, &par.depth)
        .unwrap_or_else(|e| panic!("{what}: parallel run fails Graph500 validation: {e}"));
}

#[test]
fn rmat_parallel_matches_sequential_across_configs_and_thread_counts() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(11, 21)));
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    for (s, gp) in [(2, 0), (3, 0), (2, 2), (1, 3)] {
        let (pg, _) = specialized_partition(&g, &hw(s, gp), &LayoutOptions::paper());
        let seq = run_on(&pg, PolicyKind::direction_optimized(), ExecutionMode::Sequential, root);
        for threads in [2, 4, 8] {
            let par = run_on(
                &pg,
                PolicyKind::direction_optimized(),
                ExecutionMode::Parallel(threads),
                root,
            );
            assert_equivalent(&g, &seq, &par, root, &format!("{s}S{gp}G x{threads}"));
        }
    }
}

#[test]
fn realworld_shaped_graphs_parallel_matches_sequential() {
    // The paper's crawl classes at test scale (full class sizes are
    // bench-sized); their skew exercises hub-heavy partitions.
    for class in [
        RealWorldClass::TwitterSim,
        RealWorldClass::WikipediaSim,
        RealWorldClass::LiveJournalSim,
    ] {
        let mut cfg = class.config(31);
        cfg.scale = 11;
        let g = build_csr(&kronecker(&cfg));
        let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let (pg, _) = specialized_partition(&g, &hw(2, 2), &LayoutOptions::paper());
        let seq = run_on(&pg, PolicyKind::direction_optimized(), ExecutionMode::Sequential, root);
        let par = run_on(&pg, PolicyKind::direction_optimized(), ExecutionMode::Parallel(4), root);
        assert_equivalent(&g, &seq, &par, root, class.name());
    }
}

#[test]
fn prop_parallel_equivalence_on_random_graphs() {
    // Random graphs x random hardware shapes x random thread counts x
    // random roots, both policies.
    run_cases(40, 0x9A11, |rng: &mut Xoshiro256| {
        let el = gen::edge_list(rng, 140, 600);
        let g = build_csr(&el);
        let cfg_hw = HardwareConfig {
            cpu_sockets: gen::int_in(rng, 1, 4),
            gpus: gen::int_in(rng, 0, 2),
            gpu_mem_bytes: 1 << 22,
            gpu_max_degree: 32,
        };
        let (pg, _) = specialized_partition(&g, &cfg_hw, &LayoutOptions::paper());
        let policy = if rng.next_below(2) == 0 {
            PolicyKind::direction_optimized()
        } else {
            PolicyKind::AlwaysTopDown
        };
        let threads = gen::int_in(rng, 2, 8);
        let root = rng.next_below(g.num_vertices as u64) as u32;
        let seq = run_on(&pg, policy, ExecutionMode::Sequential, root);
        let par = run_on(&pg, policy, ExecutionMode::Parallel(threads), root);
        assert_equivalent(&g, &seq, &par, root, &format!("random x{threads}"));
    });
}

#[test]
fn scale18_rmat_parallel_is_faster_than_sequential() {
    // Acceptance check: a scale-18 RMAT BFS through the hybrid engine is
    // measurably faster wall-clock with 4 worker threads than with 1.
    // Partition over 4 CPU sockets (random placement balances edge work).
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(18, 42)));
    let pg = random_partition(&g, &hw(4, 0), &LayoutOptions::paper(), 7);
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();

    let mk_runner = |exec: ExecutionMode| {
        let cfg = HybridConfig { policy: PolicyKind::direction_optimized(), exec, ..Default::default() };
        HybridRunner::<SimAccelerator>::new(&pg, cfg, None).unwrap()
    };
    let mut seq_runner = mk_runner(ExecutionMode::Sequential);
    let mut par_runner = mk_runner(ExecutionMode::Parallel(4));

    // Warm-up (page-in, buffer allocation), then interleave timed reps so
    // background load drifts affect both modes equally; take the min over
    // up to 3 rounds, stopping as soon as the speedup is visible (retries
    // absorb transient CI noise without weakening the assertion).
    seq_runner.run(root).unwrap();
    par_runner.run(root).unwrap();
    let mut seq_best = f64::INFINITY;
    let mut par_best = f64::INFINITY;
    let mut seq_run = None;
    let mut par_run = None;
    for round in 0..3 {
        for _ in 0..3 {
            let s = seq_runner.run(root).unwrap();
            seq_best = seq_best.min(s.wall.as_secs_f64());
            seq_run = Some(s);
            let p = par_runner.run(root).unwrap();
            par_best = par_best.min(p.wall.as_secs_f64());
            par_run = Some(p);
        }
        if par_best < seq_best {
            break;
        }
        eprintln!(
            "round {round}: no speedup yet (seq {seq_best:.4}s, par {par_best:.4}s); retrying"
        );
    }
    let (seq_run, par_run) = (seq_run.unwrap(), par_run.unwrap());
    assert_equivalent(&g, &seq_run, &par_run, root, "scale18 x4");

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    eprintln!(
        "scale-18 RMAT: sequential best {:.1} ms, 4-thread best {:.1} ms ({cores} cores, {:.2}x)",
        seq_best * 1e3,
        par_best * 1e3,
        seq_best / par_best
    );
    // Hosts with fewer cores than worker threads are oversubscribed by
    // construction; if even the retry rounds showed no gain there, report
    // and skip rather than fail — the assertion is about the engine, not
    // about a contended 2-vCPU runner.
    if cores < 4 && par_best >= seq_best {
        eprintln!(
            "SKIP speedup assertion: only {cores} cores for 4 worker threads \
             (oversubscribed host; equivalence above still verified)"
        );
        return;
    }
    assert!(
        par_best < seq_best,
        "4 worker threads ({par_best:.4}s) must beat sequential ({seq_best:.4}s) on {cores} cores"
    );
}
