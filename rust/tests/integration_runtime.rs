//! PJRT integration: the AOT-compiled Pallas kernels, executed through the
//! `xla` crate, must produce *bit-identical* results to the pure-Rust
//! `SimAccelerator` mirror — and the full hybrid BFS must agree with the
//! reference regardless of backend.
//!
//! Requires `make artifacts`; tests are skipped (with a note) if the
//! manifest is missing so `cargo test` stays runnable pre-build.

use totem_do::bfs::{validate_graph500, HybridConfig, HybridRunner};
use totem_do::engine::{Accelerator, SimAccelerator};
use totem_do::graph::generator::{kronecker, GeneratorConfig};
use totem_do::graph::{build_csr, Csr};
use totem_do::partition::{specialized_partition, HardwareConfig, LayoutOptions};
use totem_do::runtime::{default_artifact_dir, PjrtAccelerator};
use totem_do::util::Bitmap;

fn artifacts_available() -> bool {
    let dir = default_artifact_dir();
    if dir.join("manifest.txt").exists() {
        true
    } else {
        eprintln!(
            "SKIP: no artifacts at {} (run `make artifacts`)",
            dir.display()
        );
        false
    }
}

fn hw(s: usize, g: usize) -> HardwareConfig {
    HardwareConfig { cpu_sockets: s, gpus: g, gpu_mem_bytes: 1 << 26, gpu_max_degree: 32 }
}

fn reference_depths(g: &Csr, root: u32) -> Vec<i32> {
    let mut depth = vec![-1i32; g.num_vertices];
    depth[root as usize] = 0;
    let mut q = std::collections::VecDeque::from([root]);
    while let Some(u) = q.pop_front() {
        for &w in g.neighbours(u) {
            if depth[w as usize] < 0 {
                depth[w as usize] = depth[u as usize] + 1;
                q.push_back(w);
            }
        }
    }
    depth
}

#[test]
fn pjrt_and_sim_bottom_up_are_bit_identical() {
    if !artifacts_available() {
        return;
    }
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(11, 5)));
    let (pg, _) = specialized_partition(&g, &hw(1, 1), &LayoutOptions::paper());
    let gpu_pid = pg.parts.iter().find(|p| p.kind.is_gpu()).unwrap().id;

    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let mut pjrt = PjrtAccelerator::new(&default_artifact_dir(), g.num_vertices).unwrap();
    sim.setup(gpu_pid, &pg.parts[gpu_pid]).unwrap();
    pjrt.setup(gpu_pid, &pg.parts[gpu_pid]).unwrap();

    // A few frontier patterns, feeding visited state forward.
    let mut frontier = Bitmap::new(g.num_vertices);
    for seed in [3usize, 17, 101] {
        frontier.clear();
        for i in 0..g.num_vertices {
            if (i * 2654435761) % 7 == seed % 7 {
                frontier.set(i);
            }
        }
        let a = sim.bottom_up(gpu_pid, frontier.words()).unwrap();
        let b = pjrt.bottom_up(gpu_pid, frontier.words()).unwrap();
        assert_eq!(a.count, b.count, "seed {seed}");
        assert_eq!(a.next_frontier, b.next_frontier, "seed {seed}");
        assert_eq!(a.parent, b.parent, "seed {seed}");
    }
}

#[test]
fn pjrt_and_sim_top_down_are_bit_identical() {
    if !artifacts_available() {
        return;
    }
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(10, 6)));
    let (pg, _) = specialized_partition(&g, &hw(1, 1), &LayoutOptions::paper());
    let gpu_pid = pg.parts.iter().find(|p| p.kind.is_gpu()).unwrap().id;
    let part = &pg.parts[gpu_pid];

    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let mut pjrt = PjrtAccelerator::new(&default_artifact_dir(), g.num_vertices).unwrap();
    sim.setup(gpu_pid, part).unwrap();
    pjrt.setup(gpu_pid, part).unwrap();

    let mut frontier = vec![0i32; part.num_vertices()];
    for (i, f) in frontier.iter_mut().enumerate() {
        if i % 5 == 0 {
            *f = 1;
        }
    }
    let a = sim.top_down(gpu_pid, &frontier).unwrap();
    let b = pjrt.top_down(gpu_pid, &frontier).unwrap();
    assert_eq!(a.edges_out, b.edges_out);
    let v = g.num_vertices;
    assert_eq!(&a.active[..v], &b.active[..v]);
    assert_eq!(&a.parent[..v], &b.parent[..v]);
}

#[test]
fn full_hybrid_bfs_on_pjrt_matches_reference_and_validates() {
    if !artifacts_available() {
        return;
    }
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(12, 7)));
    let (pg, _) = specialized_partition(&g, &hw(2, 2), &LayoutOptions::paper());
    let mut pjrt = PjrtAccelerator::new(&default_artifact_dir(), g.num_vertices).unwrap();
    let mut runner = HybridRunner::new(&pg, HybridConfig::default(), Some(&mut pjrt)).unwrap();
    let roots: Vec<u32> =
        (0..g.num_vertices as u32).filter(|&v| g.degree(v) > 2).take(3).collect();
    for root in roots {
        let run = runner.run(root).unwrap();
        assert_eq!(run.depth, reference_depths(&g, root), "root {root}");
        validate_graph500(&g, root, &run.parent, &run.depth).unwrap();
    }
}

#[test]
fn pjrt_and_sim_full_runs_agree_exactly() {
    if !artifacts_available() {
        return;
    }
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(11, 8)));
    let (pg, _) = specialized_partition(&g, &hw(1, 2), &LayoutOptions::paper());
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();

    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let mut r1 = HybridRunner::new(&pg, HybridConfig::default(), Some(&mut sim)).unwrap();
    let a = r1.run(root).unwrap();

    let mut pjrt = PjrtAccelerator::new(&default_artifact_dir(), g.num_vertices).unwrap();
    let mut r2 = HybridRunner::new(&pg, HybridConfig::default(), Some(&mut pjrt)).unwrap();
    let b = r2.run(root).unwrap();

    assert_eq!(a.depth, b.depth);
    assert_eq!(a.parent, b.parent);
    assert_eq!(a.levels.len(), b.levels.len());
    for (la, lb) in a.levels.iter().zip(&b.levels) {
        assert_eq!(la.frontier_size, lb.frontier_size);
        assert_eq!(la.direction, lb.direction);
    }
}

#[test]
fn pjrt_reports_missing_artifacts_cleanly() {
    let bogus = std::path::Path::new("/nonexistent/totem-do-artifacts");
    let msg = match PjrtAccelerator::new(bogus, 1024) {
        Ok(_) => panic!("expected missing-artifacts error"),
        Err(e) => format!("{e:?}"),
    };
    assert!(msg.contains("manifest"), "unexpected error: {msg}");
}
