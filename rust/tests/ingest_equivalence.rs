//! Ingestion-pipeline determinism and scaling (DESIGN.md Section 9).
//!
//! The contract under test: the chunked parallel generators and the
//! parallel CSR builder must produce **bit-identical** output — the same
//! `EdgeList` byte for byte, the same `Csr` arrays — for any thread
//! count, across RMAT, Erdős–Rényi, and real-world-analog configurations.
//! Plus: at scale >= 17 the 4-thread end-to-end build (generate + CSR)
//! must beat the single-threaded one in wall-clock.

// Scaling assertions time real builds; wall-clock is the measurement.
#![allow(clippy::disallowed_methods)]

use totem_do::graph::generator::{
    erdos_renyi_par, kronecker_par, real_world_analog_par, GeneratorConfig, RealWorldClass,
};
use totem_do::graph::{build_csr_par, io, Csr, EdgeList};
use totem_do::partition::{specialized_partition_par, HardwareConfig, LayoutOptions};
use totem_do::util::proptest_lite::{gen, run_cases};
use totem_do::util::Xoshiro256;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// Generate + build at every thread count and assert bitwise equality.
fn assert_ingest_equivalent(mk: impl Fn(usize) -> EdgeList, what: &str) -> Csr {
    let base_el = mk(1);
    let base_csr = build_csr_par(&base_el, 1);
    base_csr.validate().unwrap_or_else(|e| panic!("{what}: invalid CSR: {e}"));
    for &threads in &THREAD_COUNTS[1..] {
        let el = mk(threads);
        assert_eq!(base_el, el, "{what}: EdgeList diverges at {threads} threads");
        let csr = build_csr_par(&base_el, threads);
        assert_eq!(base_csr, csr, "{what}: Csr diverges at {threads} threads");
    }
    base_csr
}

#[test]
fn rmat_ingest_is_bit_identical_across_thread_counts() {
    for (scale, ef, seed) in [(10, 16, 1u64), (11, 16, 42), (12, 8, 7)] {
        let cfg = GeneratorConfig { edge_factor: ef, ..GeneratorConfig::graph500(scale, seed) };
        assert_ingest_equivalent(|t| kronecker_par(&cfg, t), &format!("rmat-s{scale}-ef{ef}"));
    }
}

#[test]
fn erdos_renyi_ingest_is_bit_identical_across_thread_counts() {
    for (nv, ne, seed) in [(1 << 10, 1 << 14, 3u64), (5000, 60_000, 11), (64, 0, 5)] {
        assert_ingest_equivalent(
            |t| erdos_renyi_par(nv, ne, seed, t),
            &format!("er-{nv}v-{ne}e"),
        );
    }
}

#[test]
fn realworld_analog_ingest_is_bit_identical_across_thread_counts() {
    // The paper's crawl classes at test scale (full class sizes are
    // bench-sized): each exercises a different skew/edge-factor shape.
    for class in [
        RealWorldClass::TwitterSim,
        RealWorldClass::WikipediaSim,
        RealWorldClass::LiveJournalSim,
    ] {
        let mut cfg = class.config(31);
        cfg.scale = 11;
        assert_ingest_equivalent(|t| kronecker_par(&cfg, t), class.name());
    }
}

#[test]
fn prop_ingest_equivalence_on_random_configs() {
    run_cases(12, 0x16E57, |rng: &mut Xoshiro256| {
        // Random RMAT shape (skew varies with the initiator mass).
        let scale = gen::int_in(rng, 8, 11) as u32;
        let ef = gen::int_in(rng, 2, 24);
        let a = 0.40 + 0.25 * rng.next_f64();
        let bc = (1.0 - a) / 3.0;
        let cfg = GeneratorConfig {
            scale,
            edge_factor: ef,
            a,
            b: bc,
            c: bc,
            seed: rng.next_u64(),
        };
        assert_ingest_equivalent(|t| kronecker_par(&cfg, t), &format!("rand-rmat-s{scale}"));

        // Random ER control.
        let nv = gen::int_in(rng, 2, 4096);
        let ne = gen::int_in(rng, 0, 30_000);
        let seed = rng.next_u64();
        assert_ingest_equivalent(|t| erdos_renyi_par(nv, ne, seed, t), "rand-er");

        // Arbitrary (non-generated) edge lists through the builder alone,
        // including duplicates the generator grid can't produce.
        let el = gen::edge_list(rng, 120, 500);
        let base = build_csr_par(&el, 1);
        for &threads in &THREAD_COUNTS[1..] {
            assert_eq!(base, build_csr_par(&el, threads), "edge-list x{threads}");
        }
    });
}

#[test]
fn partition_placement_is_bit_identical_across_thread_counts() {
    let g = build_csr_par(&kronecker_par(&GeneratorConfig::graph500(11, 23), 4), 4);
    let hw = HardwareConfig { cpu_sockets: 2, gpus: 2, gpu_mem_bytes: 1 << 22, gpu_max_degree: 32 };
    let (base, plan) = specialized_partition_par(&g, &hw, &LayoutOptions::paper(), 1);
    assert!(plan.gpu_vertices > 0);
    for &threads in &THREAD_COUNTS[1..] {
        let (pg, p) = specialized_partition_par(&g, &hw, &LayoutOptions::paper(), threads);
        pg.validate(&g).unwrap();
        assert_eq!(base.owner, pg.owner, "x{threads}: placement diverges");
        assert_eq!(base.local_index, pg.local_index, "x{threads}");
        assert_eq!(plan.gpu_vertices, p.gpu_vertices, "x{threads}");
    }
}

#[test]
fn io_roundtrip_preserves_csr() {
    // write -> read -> identical CSR, both text and binary formats.
    let el = real_world_analog_par(RealWorldClass::LiveJournalSim, 2, 4);
    let el = EdgeList { num_vertices: el.num_vertices, edges: el.edges[..40_000].to_vec() };
    let g = build_csr_par(&el, 4);
    let mut base = std::env::temp_dir();
    base.push(format!("totem_do_ingest_rt_{}", std::process::id()));

    let txt = base.with_extension("txt");
    io::save_text(&el, &txt).unwrap();
    let el_txt = io::load_text(&txt, Some(el.num_vertices)).unwrap();
    assert_eq!(el, el_txt);
    assert_eq!(g, build_csr_par(&el_txt, 2), "text roundtrip changed the CSR");
    std::fs::remove_file(&txt).ok();

    let bin = base.with_extension("bin");
    io::save_binary(&el, &bin).unwrap();
    let el_bin = io::load_binary(&bin).unwrap();
    assert_eq!(el, el_bin);
    assert_eq!(g, build_csr_par(&el_bin, 4), "binary roundtrip changed the CSR");
    std::fs::remove_file(&bin).ok();
}

#[test]
fn scale17_parallel_ingest_is_faster_than_sequential() {
    // Acceptance check: the end-to-end scale-17 build (Kronecker
    // generation + CSR construction) is measurably faster wall-clock with
    // 4 worker threads than with 1.
    let cfg = GeneratorConfig::graph500(17, 42);
    let build = |threads: usize| build_csr_par(&kronecker_par(&cfg, threads), threads);

    // Warm-up (page-in, allocator reuse), then interleave timed reps so
    // background load drifts affect both modes equally; take the min over
    // up to 3 rounds, stopping as soon as the speedup is visible (retries
    // absorb transient CI noise without weakening the assertion).
    let warm = build(1);
    assert_eq!(warm, build(4), "scale-17 parallel build must be bit-identical");
    let mut seq_best = f64::INFINITY;
    let mut par_best = f64::INFINITY;
    for round in 0..3 {
        for _ in 0..2 {
            let t0 = std::time::Instant::now();
            let g1 = build(1);
            seq_best = seq_best.min(t0.elapsed().as_secs_f64());
            let t0 = std::time::Instant::now();
            let g4 = build(4);
            par_best = par_best.min(t0.elapsed().as_secs_f64());
            assert_eq!(g1.num_directed_edges(), g4.num_directed_edges());
        }
        if par_best < seq_best {
            break;
        }
        eprintln!(
            "round {round}: no speedup yet (seq {seq_best:.3}s, par {par_best:.3}s); retrying"
        );
    }

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    eprintln!(
        "scale-17 ingest: sequential best {:.1} ms, 4-thread best {:.1} ms ({cores} cores, {:.2}x)",
        seq_best * 1e3,
        par_best * 1e3,
        seq_best / par_best
    );
    // Hosts with fewer cores than worker threads are oversubscribed by
    // construction; if even the retry rounds showed no gain there, report
    // and skip rather than fail — the assertion is about the pipeline,
    // not about a contended runner.
    if cores < 4 && par_best >= seq_best {
        eprintln!(
            "SKIP speedup assertion: only {cores} cores for 4 worker threads \
             (oversubscribed host; bit-identity above still verified)"
        );
        return;
    }
    assert!(
        par_best < seq_best,
        "4-thread ingest ({par_best:.3}s) must beat sequential ({seq_best:.3}s) on {cores} cores"
    );
}
