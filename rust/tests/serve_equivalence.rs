//! Serving-front-end equivalence (DESIGN.md Section 14): the typed
//! concurrent session must be a transparent wrapper around the engine —
//! no knob of the serving layer (result cache, lane count, schedule
//! policy, arrival order, co-submitted failures, expired deadlines) may
//! change a completed query's bits relative to a standalone run.
//!
//! Families under test: cached vs uncached vs standalone bit-equality;
//! invariance to lane count / policy / arrival order; per-query failure
//! isolation in a mixed valid/invalid/expired stream (the regression net
//! for the `serve` stdin loop, which used to abort the whole session on
//! the first bad query); cache invalidation on registry swap; and the
//! pooled-state lifecycle under expired deadlines (nothing leaks,
//! serving recovers bit-identically).

use std::time::Duration;

use totem_do::bfs::{BfsRun, HybridConfig, HybridRunner};
use totem_do::engine::SimAccelerator;
use totem_do::graph::build_csr;
use totem_do::graph::generator::{kronecker, GeneratorConfig};
use totem_do::metrics;
use totem_do::partition::{HardwareConfig, LayoutOptions};
use totem_do::service::{
    serve_session, AlgoOutput, AlgoQuery, BatchOptions, GraphRegistry, QueryRequest, QueryResponse,
    QueryStatus, ResidentGraph, SchedulePolicy, ServeOptions,
};

fn hw(s: usize, g: usize) -> HardwareConfig {
    HardwareConfig { cpu_sockets: s, gpus: g, gpu_mem_bytes: 1 << 24, gpu_max_degree: 32 }
}

fn resident(scale: u32, seed: u64, cfg: &HardwareConfig) -> ResidentGraph {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(scale, seed)));
    ResidentGraph::build("g", g, cfg, &LayoutOptions::paper(), 1)
}

/// Standalone reference: a fresh runner + fresh state, exactly what one
/// `cmd_bfs` invocation does.
fn standalone(rg: &ResidentGraph, root: u32) -> BfsRun {
    let mut sim = (rg.hw.gpus > 0)
        .then(|| SimAccelerator::new(rg.pg.parts.len(), rg.num_vertices()));
    let cfg = HybridConfig::default();
    let mut runner = HybridRunner::new(&rg.pg, cfg, sim.as_mut()).unwrap();
    runner.run(root).unwrap()
}

fn bfs_out(resp: &QueryResponse) -> &BfsRun {
    match resp.output() {
        Some(AlgoOutput::Bfs(run)) => run,
        other => panic!("expected a BFS completion, got {other:?} ({:?})", resp.status),
    }
}

fn assert_same_run(reference: &BfsRun, got: &BfsRun, what: &str) {
    assert_eq!(reference.root, got.root, "{what}");
    assert_eq!(reference.depth, got.depth, "{what}: level assignments diverge");
    assert_eq!(reference.parent, got.parent, "{what}: parent trees diverge");
    assert_eq!(reference.levels, got.levels, "{what}: per-level stats diverge");
    assert_eq!(reference.init_bytes, got.init_bytes, "{what}: modeled init bytes diverge");
    assert_eq!(reference.aggregation_bytes, got.aggregation_bytes, "{what}");
    assert_eq!(reference.reached_vertices, got.reached_vertices, "{what}");
    assert_eq!(reference.reached_edge_endpoints, got.reached_edge_endpoints, "{what}");
}

fn bfs(root: u32) -> QueryRequest {
    QueryRequest::new(AlgoQuery::Bfs { root })
}

fn serve_opts(lanes: usize, cache_capacity: usize) -> ServeOptions {
    ServeOptions {
        batch: BatchOptions { threads: lanes, max_concurrency: lanes, ..Default::default() },
        queue_depth: 64,
        cache_capacity,
        ..Default::default()
    }
}

/// Memoization must be invisible in the bits: with the cache on, the
/// second pass over the same roots answers from the memo (`cache_hit`
/// set) yet every response — hit or miss, CPU-only or hybrid — equals
/// the standalone reference exactly. With the cache off, nothing is
/// memoized and the bits still match.
#[test]
fn cached_and_uncached_serving_bit_identical_to_standalone() {
    for cfg_hw in [hw(2, 0), hw(2, 2)] {
        let rg = resident(10, 11, &cfg_hw);
        let roots = metrics::sample_roots(rg.num_vertices(), |v| rg.degree(v), 4, 3);
        let reference: Vec<BfsRun> = roots.iter().map(|&r| standalone(&rg, r)).collect();
        for cache_capacity in [0usize, 64] {
            // Single lane: FIFO service order, so pass 1 is all misses
            // and pass 2 all hits — deterministically.
            let opts = serve_opts(1, cache_capacity);
            let report = serve_session(&rg, &opts, |s| {
                for _pass in 0..2 {
                    for &r in &roots {
                        s.submit(bfs(r));
                    }
                }
            });
            assert_eq!(report.responses.len(), roots.len() * 2);
            for (i, resp) in report.responses.iter().enumerate() {
                let what = format!("{} cache_cap={cache_capacity} query {i}", cfg_hw.label());
                assert_eq!(resp.status, QueryStatus::Done, "{what}");
                let expect_hit = cache_capacity > 0 && i >= roots.len();
                assert_eq!(resp.timings.cache_hit, expect_hit, "{what}: cache flag");
                assert_same_run(&reference[i % roots.len()], bfs_out(resp), &what);
            }
            if cache_capacity == 0 {
                assert!(rg.cache.is_empty(), "capacity 0 must disable memoization");
            } else {
                assert_eq!(rg.cache.len(), roots.len());
            }
            rg.cache.clear();
        }
    }
}

/// Lane count, schedule policy, and arrival order pick *which lane runs
/// what when* — never what a query answers.
#[test]
fn serving_invariant_to_lane_count_policy_and_arrival_order() {
    let rg = resident(10, 21, &hw(2, 2));
    let roots = metrics::sample_roots(rg.num_vertices(), |v| rg.degree(v), 8, 4);
    let reference: Vec<BfsRun> = roots.iter().map(|&r| standalone(&rg, r)).collect();
    for lanes in [1usize, 2, 4] {
        for policy in [SchedulePolicy::Throughput, SchedulePolicy::Latency] {
            for reversed in [false, true] {
                let mut opts = serve_opts(lanes, 8);
                opts.batch.policy = policy;
                let order: Vec<usize> = if reversed {
                    (0..roots.len()).rev().collect()
                } else {
                    (0..roots.len()).collect()
                };
                let report = serve_session(&rg, &opts, |s| {
                    for &i in &order {
                        s.submit(bfs(roots[i]));
                    }
                });
                assert_eq!(report.counts.done, roots.len() as u64);
                for (slot, resp) in report.responses.iter().enumerate() {
                    let i = order[slot];
                    let what = format!(
                        "lanes={lanes} policy={policy:?} reversed={reversed} root {}",
                        roots[i]
                    );
                    assert_same_run(&reference[i], bfs_out(resp), &what);
                }
            }
        }
    }
}

/// The `serve` regression (one bad query used to abort the session):
/// invalid roots and expired deadlines answer their own slot only;
/// every co-submitted valid query completes bit-identically.
#[test]
fn mixed_stream_isolates_failures_per_query() {
    let rg = resident(9, 5, &hw(2, 0));
    let n = rg.num_vertices() as u32;
    let good = metrics::sample_roots(rg.num_vertices(), |v| rg.degree(v), 3, 8);
    let reference: Vec<BfsRun> = good.iter().map(|&r| standalone(&rg, r)).collect();
    let report = serve_session(&rg, &serve_opts(2, 0), |s| {
        s.submit(bfs(good[0]));
        s.submit(bfs(n + 7));
        s.submit(bfs(good[1]));
        s.submit(bfs(good[2]).with_deadline(Duration::ZERO));
        s.submit(bfs(good[2]));
    });
    let r = &report.responses;
    assert_eq!(r.len(), 5, "every submission is answered");
    assert_eq!(r[1].status, QueryStatus::InvalidRoot);
    let msg = r[1].error.as_deref().unwrap_or("");
    assert!(msg.contains("out of range"), "{msg}");
    assert_eq!(r[3].status, QueryStatus::DeadlineExceeded);
    assert_same_run(&reference[0], bfs_out(&r[0]), "valid before the invalid root");
    assert_same_run(&reference[1], bfs_out(&r[2]), "valid after the invalid root");
    assert_same_run(&reference[2], bfs_out(&r[4]), "valid after the expired deadline");
    assert_eq!(report.counts.done, 3);
    assert_eq!(report.counts.invalid_root, 1);
    assert_eq!(report.counts.deadline_exceeded, 1);
}

/// Registry swap is the cache-coherence point: the displaced graph's
/// memo is cleared *before* the new Arc is visible, so a session still
/// holding the old graph recomputes instead of serving stale bits.
#[test]
fn registry_swap_invalidates_the_displaced_cache() {
    let registry = GraphRegistry::new();
    let old = registry.insert(resident(9, 5, &hw(2, 0))).expect("fresh registry");
    let root = metrics::sample_roots(old.num_vertices(), |v| old.degree(v), 1, 2)[0];
    let opts = serve_opts(1, 8);
    let report = serve_session(&old, &opts, |s| {
        s.submit(bfs(root));
        s.submit(bfs(root));
    });
    assert_eq!(report.counts.cache_hits, 1, "second ask was memoized");
    assert_eq!(old.cache.len(), 1);

    let fresh = registry.swap(resident(9, 6, &hw(2, 0)));
    assert!(old.cache.is_empty(), "displaced entry's cache must be cleared on swap");
    assert!(fresh.cache.is_empty(), "the replacement starts cold");

    // A holder of the displaced Arc recomputes rather than serving the
    // stale memo — and the recomputation still matches standalone.
    let report = serve_session(&old, &opts, |s| {
        s.submit(bfs(root));
    });
    assert!(!report.responses[0].timings.cache_hit, "stale memo must not resurface");
    assert_same_run(&standalone(&old, root), bfs_out(&report.responses[0]), "post-swap recompute");
}

/// Deadline-expired queries must be free: answered without consuming
/// pooled traversal state, leaking nothing, and leaving the pool able
/// to serve bit-identical results afterwards.
#[test]
fn expired_deadlines_release_pool_state_and_serving_recovers() {
    let rg = resident(9, 7, &hw(2, 0));
    let roots = metrics::sample_roots(rg.num_vertices(), |v| rg.degree(v), 4, 2);
    let reference: Vec<BfsRun> = roots.iter().map(|&r| standalone(&rg, r)).collect();
    let normal = serve_opts(2, 0);

    let report = serve_session(&rg, &normal, |s| {
        for &r in &roots {
            s.submit(bfs(r));
        }
    });
    assert_eq!(report.counts.done, roots.len() as u64);
    let created = rg.states.stats().created;
    assert!(created >= 1, "the warm round allocated pooled state");
    assert_eq!(rg.states.stats().idle, created, "all states parked after the round");

    let expired = ServeOptions { default_deadline: Some(Duration::ZERO), ..normal };
    let report = serve_session(&rg, &expired, |s| {
        for &r in &roots {
            s.submit(bfs(r));
        }
    });
    assert!(report.responses.iter().all(|r| r.status == QueryStatus::DeadlineExceeded));
    let st = rg.states.stats();
    assert_eq!(st.created, created, "expired queries consumed no pooled state");
    assert_eq!(st.idle, st.created, "nothing leaked");

    let report = serve_session(&rg, &normal, |s| {
        for &r in &roots {
            s.submit(bfs(r));
        }
    });
    for (i, resp) in report.responses.iter().enumerate() {
        assert_same_run(&reference[i], bfs_out(resp), &format!("post-expiry query {i}"));
    }
}
