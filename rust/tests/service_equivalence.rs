//! Service-layer equivalence (DESIGN.md Section 11): the query-level
//! determinism contract, state-pool recycling correctness, and registry
//! sharing across concurrent batches.
//!
//! The contract under test: every query completed through the batched
//! scheduler must be **bit-identical** to a standalone run of the same
//! root over the same partitioning — same depths, same parent tree, same
//! per-level stats and byte counters — regardless of batch size (1/4/16),
//! schedule policy, thread count, batch composition, or whether its
//! traversal state came fresh from the allocator, recycled from a clean
//! query (the O(touched) sparse reset), or recycled from a *failed*
//! query (poisoned, full wipe).
//!
//! The CI matrix exports `TOTEM_DO_TEST_THREADS`; values above 1 join the
//! tested thread ladder, so both legs exercise genuinely different
//! schedules of the same bit-identical query stream.

// This suite deliberately keeps exercising the deprecated `run_batch`
// shim until its removal — it is the regression net proving the shim
// stays bit-identical to the typed path it wraps. The typed-surface
// equivalents live in `serve_equivalence.rs`.
#![allow(deprecated)]

use std::sync::Arc;

use totem_do::bfs::{BfsRun, HybridConfig, HybridRunner};
use totem_do::engine::SimAccelerator;
use totem_do::graph::generator::{kronecker, GeneratorConfig};
use totem_do::graph::{build_csr, EdgeList};
use totem_do::metrics;
use totem_do::partition::{HardwareConfig, LayoutOptions};
use totem_do::service::{
    run_batch, BatchOptions, GraphRegistry, QueryOutcome, ResidentGraph, SchedulePolicy,
};

fn hw(s: usize, g: usize) -> HardwareConfig {
    HardwareConfig { cpu_sockets: s, gpus: g, gpu_mem_bytes: 1 << 24, gpu_max_degree: 32 }
}

fn thread_ladder() -> Vec<usize> {
    let mut ts = vec![1, 2, 4];
    if let Some(t) =
        std::env::var("TOTEM_DO_TEST_THREADS").ok().and_then(|s| s.parse::<usize>().ok())
    {
        if !ts.contains(&t) {
            ts.push(t);
        }
    }
    ts
}

/// Standalone reference: a fresh runner + fresh state per root, exactly
/// what one `cmd_bfs` invocation does.
fn standalone(rg: &ResidentGraph, root: u32) -> BfsRun {
    let mut sim = (rg.hw.gpus > 0)
        .then(|| SimAccelerator::new(rg.pg.parts.len(), rg.num_vertices()));
    let cfg = HybridConfig::default();
    let mut runner = HybridRunner::new(&rg.pg, cfg, sim.as_mut()).unwrap();
    runner.run(root).unwrap()
}

fn assert_same_run(reference: &BfsRun, got: &BfsRun, what: &str) {
    assert_eq!(reference.root, got.root, "{what}");
    assert_eq!(reference.depth, got.depth, "{what}: level assignments diverge");
    assert_eq!(reference.parent, got.parent, "{what}: parent trees diverge");
    assert_eq!(reference.levels, got.levels, "{what}: per-level stats diverge");
    assert_eq!(reference.init_bytes, got.init_bytes, "{what}: modeled init bytes diverge");
    assert_eq!(reference.aggregation_bytes, got.aggregation_bytes, "{what}");
    assert_eq!(reference.reached_vertices, got.reached_vertices, "{what}");
    assert_eq!(reference.reached_edge_endpoints, got.reached_edge_endpoints, "{what}");
}

fn resident(scale: u32, seed: u64, cfg: &HardwareConfig) -> ResidentGraph {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(scale, seed)));
    ResidentGraph::build("t", g, cfg, &LayoutOptions::paper(), 1)
}

#[test]
fn batched_queries_bit_identical_to_standalone_across_batch_and_threads() {
    for cfg_hw in [hw(2, 0), hw(2, 2)] {
        let rg = resident(10, 11, &cfg_hw);
        let roots =
            metrics::sample_roots(rg.num_vertices(), |v| rg.degree(v), 16, 3);
        assert_eq!(roots.len(), 16);
        let reference: Vec<BfsRun> = roots.iter().map(|&r| standalone(&rg, r)).collect();

        for batch in [1usize, 4, 16] {
            for threads in thread_ladder() {
                for policy in [SchedulePolicy::Throughput, SchedulePolicy::Latency] {
                    let opts = BatchOptions {
                        threads,
                        policy,
                        max_concurrency: batch,
                        ..Default::default()
                    };
                    let outcomes = run_batch(&rg, &roots, &opts).unwrap();
                    for (i, outcome) in outcomes.iter().enumerate() {
                        let run = outcome.run().unwrap_or_else(|| {
                            panic!("query {i} failed under batch={batch} threads={threads}")
                        });
                        assert_same_run(
                            &reference[i],
                            run,
                            &format!(
                                "{} root {} batch={batch} threads={threads} policy={policy:?}",
                                cfg_hw.label(),
                                roots[i]
                            ),
                        );
                    }
                }
            }
        }
    }
}

/// The O(touched) sparse recycle must be invisible: a runner whose state
/// alternates between a tiny component (sparse reset) and the giant
/// component (full reset) must keep producing bit-identical output.
#[test]
fn recycled_state_sparse_reset_is_bit_identical() {
    // Vertices 0..3: an isolated 3-chain (touched << V/8). The rest: a
    // long chain, so its traversal touches most of the graph.
    let n = 2048usize;
    let mut edges = vec![(0u32, 1u32), (1, 2)];
    edges.extend((3..n as u32 - 1).map(|v| (v, v + 1)));
    let g = build_csr(&EdgeList { num_vertices: n, edges });
    let rg = ResidentGraph::build("chain", g, &hw(2, 0), &LayoutOptions::paper(), 1);

    let reference_small = standalone(&rg, 0);
    let reference_big = standalone(&rg, 500);
    assert!(reference_small.reached_vertices == 3, "tiny component sanity");
    assert!(reference_big.reached_vertices > (n / 2) as u64, "giant component sanity");

    // One resident runner, alternating components: small roots take the
    // sparse recycle, big roots force the full wipe, and every run must
    // match its fresh-runner reference exactly (including modeled bytes).
    let mut runner =
        HybridRunner::<SimAccelerator>::new(&rg.pg, HybridConfig::default(), None).unwrap();
    for (round, root) in [0u32, 500, 0, 0, 500, 0].into_iter().enumerate() {
        let run = runner.run(root).unwrap();
        let reference = if root == 0 { &reference_small } else { &reference_big };
        assert_same_run(reference, &run, &format!("round {round} root {root}"));
    }
}

/// A state released after a failed (mid-run) query is poisoned; the pool
/// must hand it back healed — the next query through the service sees
/// pristine state and bit-identical results.
#[test]
fn poisoned_pool_state_self_heals_through_the_service() {
    let rg = resident(9, 5, &hw(2, 0));
    let roots = metrics::sample_roots(rg.num_vertices(), |v| rg.degree(v), 4, 8);
    let reference: Vec<BfsRun> = roots.iter().map(|&r| standalone(&rg, r)).collect();

    // Poison a pooled state by hand: a partial traversal that never
    // finished (what an errored query leaves behind).
    let mut state = rg.states.acquire(&rg.pg);
    state.reset();
    state.set_root(0, roots[0]);
    state.activate_local(0, roots[1], roots[0], 1);
    state.record_contrib(0, roots[2], roots[0], 0);
    rg.states.release(state);

    // Single lane so the poisoned state is definitely the one recycled.
    let opts = BatchOptions { threads: 1, max_concurrency: 1, ..Default::default() };
    let outcomes = run_batch(&rg, &roots, &opts).unwrap();
    assert!(rg.states.stats().recycled >= 1, "the poisoned state was reused");
    for (i, outcome) in outcomes.iter().enumerate() {
        assert_same_run(&reference[i], outcome.run().unwrap(), &format!("query {i}"));
    }
}

/// Root admission: an out-of-range root fails its own slot only; an
/// isolated root completes trivially. Neither disturbs its batch mates.
#[test]
fn root_validation_is_per_query() {
    let g = build_csr(&EdgeList { num_vertices: 64, edges: vec![(0, 1), (1, 2), (2, 3)] });
    let rg = ResidentGraph::build("v", g, &hw(2, 0), &LayoutOptions::paper(), 1);
    let reference = standalone(&rg, 1);
    let roots = [1u32, 9999, 63, 2];
    let outcomes =
        run_batch(&rg, &roots, &BatchOptions { threads: 2, ..Default::default() }).unwrap();
    assert_same_run(&reference, outcomes[0].run().unwrap(), "valid root");
    match &outcomes[1] {
        QueryOutcome::Failed { root, error } => {
            assert_eq!(*root, 9999);
            assert!(error.contains("out of range"), "{error}");
        }
        other => panic!("expected clean rejection, got {other:?}"),
    }
    let trivial = outcomes[2].run().expect("isolated root is valid");
    assert_eq!(trivial.reached_vertices, 1);
    assert_eq!(trivial.traversed_edges(), 0);
    assert!(outcomes[3].is_complete());
}

/// One registry entry, shared immutably across concurrently running
/// batches on separate OS threads — every query everywhere bit-identical
/// to its standalone reference, and the pool never leaks states.
#[test]
fn registry_shared_across_concurrent_batches() {
    let registry = GraphRegistry::new();
    let rg = registry
        .insert(resident(10, 21, &hw(2, 2)))
        .expect("fresh registry");
    let roots = metrics::sample_roots(rg.num_vertices(), |v| rg.degree(v), 8, 4);
    let reference: Vec<BfsRun> = roots.iter().map(|&r| standalone(&rg, r)).collect();

    std::thread::scope(|s| {
        for batch in [1usize, 4, 8] {
            let rg: Arc<ResidentGraph> = Arc::clone(&rg);
            let roots = &roots;
            let reference = &reference;
            s.spawn(move || {
                let opts = BatchOptions {
                    threads: 2,
                    max_concurrency: batch,
                    ..Default::default()
                };
                let outcomes = run_batch(&rg, roots, &opts).unwrap();
                for (i, outcome) in outcomes.iter().enumerate() {
                    assert_same_run(
                        &reference[i],
                        outcome.run().unwrap(),
                        &format!("concurrent batch={batch} query {i}"),
                    );
                }
            });
        }
    });
    let pool = rg.states.stats();
    assert_eq!(pool.idle, pool.created, "every state returned to the pool");
    assert!(
        registry.get("t").is_some(),
        "registry still serves the resident graph after the batches"
    );
}
