//! Property-based invariants over random graphs, partitionings and roots
//! (in-repo property substrate; proptest is not vendored offline).

use totem_do::algo::{run_bfs_program, run_cc, run_pagerank, run_sssp, WeightFn};
use totem_do::bfs::{validate_graph500, HybridConfig, HybridRunner, PolicyKind};
use totem_do::engine::state::{PARENT_REMOTE, PARENT_UNSET};
use totem_do::engine::{ExecutionMode, SimAccelerator};
use totem_do::graph::generator::{erdos_renyi, kronecker, GeneratorConfig};
use totem_do::graph::{build_csr, Csr};
use totem_do::partition::{specialized_partition, HardwareConfig, LayoutOptions};
use totem_do::util::proptest_lite::{gen, run_cases};
use totem_do::util::Xoshiro256;

fn hw(rng: &mut Xoshiro256) -> HardwareConfig {
    HardwareConfig {
        cpu_sockets: gen::int_in(rng, 1, 3),
        gpus: gen::int_in(rng, 0, 3),
        gpu_mem_bytes: 1 << gen::int_in(rng, 10, 24),
        gpu_max_degree: [4usize, 16, 32][gen::int_in(rng, 0, 2)],
    }
}

fn reference_depths(g: &Csr, root: u32) -> Vec<i32> {
    let mut depth = vec![-1i32; g.num_vertices];
    depth[root as usize] = 0;
    let mut q = std::collections::VecDeque::from([root]);
    while let Some(u) = q.pop_front() {
        for &w in g.neighbours(u) {
            if depth[w as usize] < 0 {
                depth[w as usize] = depth[u as usize] + 1;
                q.push_back(w);
            }
        }
    }
    depth
}

/// Run one hybrid BFS under a random configuration; return (run, graph).
fn random_run(rng: &mut Xoshiro256) -> (totem_do::bfs::BfsRun, Csr, u32) {
    let el = gen::edge_list(rng, 120, 500);
    let g = build_csr(&el);
    let cfg_hw = hw(rng);
    let (pg, _) = specialized_partition(&g, &cfg_hw, &LayoutOptions::paper());
    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let accel = if cfg_hw.gpus > 0 { Some(&mut sim) } else { None };
    let policy = if rng.next_below(2) == 0 {
        PolicyKind::direction_optimized()
    } else {
        PolicyKind::AlwaysTopDown
    };
    let cfg = HybridConfig { policy, ..Default::default() };
    let mut runner = HybridRunner::new(&pg, cfg, accel).unwrap();
    let root = rng.next_below(g.num_vertices as u64) as u32;
    let run = runner.run(root).unwrap();
    (run, g, root)
}

#[test]
fn prop_depths_equal_reference_bfs() {
    run_cases(120, 0xBF5, |rng| {
        let (run, g, root) = random_run(rng);
        assert_eq!(run.depth, reference_depths(&g, root));
    });
}

#[test]
fn prop_parent_tree_passes_graph500_validation() {
    run_cases(120, 0xAA7, |rng| {
        let (run, g, root) = random_run(rng);
        validate_graph500(&g, root, &run.parent, &run.depth).unwrap();
    });
}

#[test]
fn prop_no_remote_sentinels_survive_aggregation() {
    run_cases(80, 0x0DD, |rng| {
        let (run, _, _) = random_run(rng);
        assert!(run.parent.iter().all(|&p| p != PARENT_REMOTE));
        for (v, (&p, &d)) in run.parent.iter().zip(&run.depth).enumerate() {
            assert_eq!(p == PARENT_UNSET, d < 0, "vertex {v}: parent/depth disagree");
        }
    });
}

#[test]
fn prop_frontier_census_conservation() {
    // Sum of per-level frontiers = reached vertices; level-0 frontier = 1.
    run_cases(80, 0x5EED, |rng| {
        let (run, _, _) = random_run(rng);
        let fsum: u64 = run.levels.iter().map(|l| l.frontier_size).sum();
        assert_eq!(fsum, run.reached_vertices);
        if let Some(l0) = run.levels.first() {
            assert_eq!(l0.frontier_size, 1);
        }
    });
}

#[test]
fn prop_activations_cover_reached_set() {
    // Total activations (incl. root) = reached vertices.
    run_cases(80, 0xACE, |rng| {
        let (run, _, _) = random_run(rng);
        let activated: u64 = run
            .levels
            .iter()
            .flat_map(|l| l.pe_work.iter())
            .map(|w| w.activated)
            .sum();
        // Crossing activations may double-count merged duplicates; the
        // reached set is a lower bound and activations an upper bound.
        assert!(activated + 1 >= run.reached_vertices, "{activated} + root < {}", run.reached_vertices);
    });
}

#[test]
fn prop_comm_bytes_bounded_by_graph_size() {
    run_cases(60, 0xC033, |rng| {
        let (run, g, _) = random_run(rng);
        let bitmap_bound = (g.num_vertices as u64 / 8 + 64) * 16; // generous per-level cap
        for l in &run.levels {
            assert!(l.comm.push_bytes() <= bitmap_bound * 4);
            assert!(l.comm.pull_bytes() <= bitmap_bound * 4);
        }
    });
}

#[test]
fn prop_connected_graphs_reach_everything() {
    run_cases(60, 0xF00D, |rng| {
        let el = gen::connected_graph(rng, 80, 150);
        let g = build_csr(&el);
        let cfg_hw = hw(rng);
        let (pg, _) = specialized_partition(&g, &cfg_hw, &LayoutOptions::paper());
        let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
        let accel = if cfg_hw.gpus > 0 { Some(&mut sim) } else { None };
        let mut runner = HybridRunner::new(&pg, HybridConfig::default(), accel).unwrap();
        let root = rng.next_below(g.num_vertices as u64) as u32;
        let run = runner.run(root).unwrap();
        assert_eq!(run.reached_vertices as usize, g.num_vertices);
        assert_eq!(run.traversed_edges() as usize, g.num_undirected_edges());
    });
}

#[test]
fn prop_partitioning_owner_maps_are_bijective() {
    run_cases(80, 0xB1B, |rng| {
        let el = gen::edge_list(rng, 100, 400);
        let g = build_csr(&el);
        let (pg, _) = specialized_partition(&g, &hw(rng), &LayoutOptions::paper());
        pg.validate(&g).unwrap();
    });
}

#[test]
fn prop_border_renumbering_roundtrips_as_inverse_bijection() {
    // Random RMAT / Erdos-Renyi / uniform workloads under random
    // partitionings: for every partition pair, global -> border-local ->
    // global must round-trip as an inverse bijection over exactly the
    // vertices owned by `p` with at least one edge into `q`.
    run_cases(40, 0xB02D, |rng| {
        let el = match rng.next_below(3) {
            0 => kronecker(&GeneratorConfig::graph500(
                gen::int_in(rng, 5, 7) as u32,
                rng.next_u64(),
            )),
            1 => erdos_renyi(gen::int_in(rng, 16, 160), gen::int_in(rng, 0, 500), rng.next_u64()),
            _ => gen::edge_list(rng, 120, 400),
        };
        let g = build_csr(&el);
        let (pg, _) = specialized_partition(&g, &hw(rng), &LayoutOptions::paper());
        let np = pg.parts.len();
        for p in 0..np {
            for q in 0..np {
                let table = pg.borders.table(p, q);
                assert!(
                    table.windows(2).all(|w| w[0] < w[1]),
                    "({p},{q}): table must be strictly ascending"
                );
                for (i, &gid) in table.iter().enumerate() {
                    assert_eq!(pg.borders.local_of(p, q, gid), Some(i as u32), "global->local");
                    assert_eq!(pg.borders.global_of(p, q, i as u32), gid, "local->global");
                }
                // Membership is exactly "owned by p with an edge into q".
                for v in 0..g.num_vertices as u32 {
                    let expect = p != q
                        && pg.owner_of(v) == p
                        && g.neighbours(v).iter().any(|&w| pg.owner_of(w) == q);
                    assert_eq!(
                        pg.borders.local_of(p, q, v).is_some(),
                        expect,
                        "vertex {v} pair ({p},{q})"
                    );
                }
            }
        }
    });
}

/// BFS-regression pin for the vertex-program refactor: on CPU-only
/// placements the generic runner must reproduce the pre-refactor
/// `HybridRunner` *exactly* — parents, levels, and the per-level
/// direction schedule — at every thread count. (pe_work/comm models are
/// intentionally not pinned: the frameworks price kernels differently.)
#[test]
fn prop_vertex_program_bfs_reproduces_hybrid_cpu_exactly() {
    run_cases(60, 0xBF60, |rng| {
        let el = gen::edge_list(rng, 120, 500);
        let g = build_csr(&el);
        let cfg_hw = HardwareConfig {
            cpu_sockets: gen::int_in(rng, 1, 3),
            gpus: 0,
            gpu_mem_bytes: 0,
            gpu_max_degree: 32,
        };
        let (pg, _) = specialized_partition(&g, &cfg_hw, &LayoutOptions::paper());
        let policy = if rng.next_below(2) == 0 {
            PolicyKind::direction_optimized()
        } else {
            PolicyKind::AlwaysTopDown
        };
        let root = rng.next_below(g.num_vertices as u64) as u32;
        let accel: Option<&mut SimAccelerator> = None;
        let mut runner =
            HybridRunner::new(&pg, HybridConfig { policy, ..Default::default() }, accel)
                .unwrap();
        let hybrid = runner.run(root).unwrap();
        for threads in [1usize, 4] {
            let prog =
                run_bfs_program(&pg, root, policy, ExecutionMode::from_threads(threads))
                    .unwrap();
            assert_eq!(prog.depth, hybrid.depth, "threads={threads}: depths diverge");
            assert_eq!(prog.parent, hybrid.parent, "threads={threads}: parents diverge");
            assert_eq!(prog.levels.len(), hybrid.levels.len(), "level-schedule length");
            for (pl, hl) in prog.levels.iter().zip(&hybrid.levels) {
                assert_eq!(pl.direction, hl.direction, "level {}: direction", hl.level);
                assert_eq!(pl.frontier_size, hl.frontier_size, "level {}", hl.level);
                assert_eq!(pl.frontier_degree_sum, hl.frontier_degree_sum, "level {}", hl.level);
            }
        }
    });
}

/// On GPU placements the accelerator kernels visit neighbours in SELL
/// order, so parent *choices* may legitimately differ from the generic
/// runner's queue order — but depths, the direction schedule, and
/// Graph500 validity must agree.
#[test]
fn prop_vertex_program_bfs_matches_hybrid_on_gpu_placements() {
    run_cases(40, 0xBF61, |rng| {
        let el = gen::edge_list(rng, 120, 500);
        let g = build_csr(&el);
        let cfg_hw = HardwareConfig {
            cpu_sockets: gen::int_in(rng, 1, 2),
            gpus: gen::int_in(rng, 1, 2),
            gpu_mem_bytes: 1 << gen::int_in(rng, 14, 22),
            gpu_max_degree: [4usize, 16, 32][gen::int_in(rng, 0, 2)],
        };
        let (pg, _) = specialized_partition(&g, &cfg_hw, &LayoutOptions::paper());
        let policy = if rng.next_below(2) == 0 {
            PolicyKind::direction_optimized()
        } else {
            PolicyKind::AlwaysTopDown
        };
        let root = rng.next_below(g.num_vertices as u64) as u32;
        let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
        let mut runner =
            HybridRunner::new(&pg, HybridConfig { policy, ..Default::default() }, Some(&mut sim))
                .unwrap();
        let hybrid = runner.run(root).unwrap();
        let prog = run_bfs_program(&pg, root, policy, ExecutionMode::Sequential).unwrap();
        assert_eq!(prog.depth, hybrid.depth, "depths diverge on GPU placement");
        assert_eq!(prog.levels.len(), hybrid.levels.len(), "level-schedule length");
        for (pl, hl) in prog.levels.iter().zip(&hybrid.levels) {
            assert_eq!(pl.direction, hl.direction, "level {}: direction", hl.level);
            assert_eq!(pl.frontier_size, hl.frontier_size, "level {}", hl.level);
        }
        validate_graph500(&g, root, &prog.parent, &prog.depth).unwrap();
    });
}

/// Per-algorithm determinism thread-ladder: SSSP distances/parents/
/// round schedules, CC labels, and PageRank ranks (bit-identical f64s)
/// must not depend on the kernel thread count.
#[test]
fn prop_algo_outputs_are_thread_invariant() {
    run_cases(30, 0xA160, |rng| {
        let el = gen::edge_list(rng, 100, 400);
        let g = build_csr(&el);
        let (pg, _) = specialized_partition(&g, &hw(rng), &LayoutOptions::paper());
        let root = rng.next_below(g.num_vertices as u64) as u32;
        // Draw per-case knobs once, before the ladder.
        let delta = 1 + rng.next_below(8);
        let w = WeightFn::Hashed { seed: rng.next_u64(), max_weight: 1 + rng.next_below(10) };
        let s0 = run_sssp(&pg, root, delta, w.clone(), ExecutionMode::Sequential).unwrap();
        let c0 = run_cc(&pg, ExecutionMode::Sequential).unwrap();
        let p0 = run_pagerank(&pg, 0.85, 20, 0.0, ExecutionMode::Sequential).unwrap();
        for threads in [2usize, 4] {
            let exec = ExecutionMode::from_threads(threads);
            let s = run_sssp(&pg, root, delta, w.clone(), exec).unwrap();
            assert_eq!(s.dist, s0.dist, "threads={threads}");
            assert_eq!(s.parent, s0.parent, "threads={threads}");
            assert_eq!(s.rounds, s0.rounds, "threads={threads}");
            let c = run_cc(&pg, exec).unwrap();
            assert_eq!(c.labels, c0.labels, "threads={threads}");
            let p = run_pagerank(&pg, 0.85, 20, 0.0, exec).unwrap();
            assert_eq!(p.ranks, p0.ranks, "threads={threads} (bit-identical f64s)");
            assert_eq!(p.iterations, p0.iterations, "threads={threads}");
        }
    });
}
