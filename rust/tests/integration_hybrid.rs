//! Cross-module integration: the hybrid partitioned BFS against baselines
//! and references, across hardware configs, policies, partitioners, and
//! graph families — all on the Sim accelerator (no artifacts needed).

use totem_do::bfs::{
    baseline_bfs, validate_graph500, BaselineKind, HybridConfig, HybridRunner, PolicyKind,
};
use totem_do::engine::{CommMode, Direction, SimAccelerator};
use totem_do::graph::generator::{erdos_renyi, kronecker, real_world_analog, GeneratorConfig, RealWorldClass};
use totem_do::graph::{build_csr, Csr, EdgeList};
use totem_do::partition::{
    random_partition, specialized_partition, HardwareConfig, LayoutOptions,
};

fn hw(s: usize, g: usize) -> HardwareConfig {
    HardwareConfig { cpu_sockets: s, gpus: g, gpu_mem_bytes: 1 << 26, gpu_max_degree: 32 }
}

fn reference_depths(g: &Csr, root: u32) -> Vec<i32> {
    let mut depth = vec![-1i32; g.num_vertices];
    depth[root as usize] = 0;
    let mut q = std::collections::VecDeque::from([root]);
    while let Some(u) = q.pop_front() {
        for &w in g.neighbours(u) {
            if depth[w as usize] < 0 {
                depth[w as usize] = depth[u as usize] + 1;
                q.push_back(w);
            }
        }
    }
    depth
}

fn check_hybrid(g: &Csr, cfg_hw: &HardwareConfig, policy: PolicyKind, root: u32) {
    let (pg, _) = specialized_partition(g, cfg_hw, &LayoutOptions::paper());
    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let accel = if cfg_hw.gpus > 0 { Some(&mut sim) } else { None };
    let cfg = HybridConfig { policy, ..Default::default() };
    let mut runner = HybridRunner::new(&pg, cfg, accel).unwrap();
    let run = runner.run(root).unwrap();
    assert_eq!(run.depth, reference_depths(g, root), "config {}", cfg_hw.label());
    validate_graph500(g, root, &run.parent, &run.depth).unwrap();
}

#[test]
fn all_hardware_configs_agree_on_kron() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(11, 1)));
    let root = (0..g.num_vertices as u32).find(|&v| g.degree(v) > 3).unwrap();
    for (s, gp) in [(1, 0), (2, 0), (1, 1), (2, 1), (1, 2), (2, 2), (3, 3)] {
        check_hybrid(&g, &hw(s, gp), PolicyKind::direction_optimized(), root);
        check_hybrid(&g, &hw(s, gp), PolicyKind::AlwaysTopDown, root);
    }
}

#[test]
fn works_on_non_scale_free_graphs() {
    let g = build_csr(&erdos_renyi(2048, 8192, 3));
    let root = (0..2048u32).find(|&v| g.degree(v) > 0).unwrap();
    check_hybrid(&g, &hw(2, 2), PolicyKind::direction_optimized(), root);
}

#[test]
fn works_on_real_world_analogs() {
    // Scaled-down versions (the full classes are bench-sized).
    for class in [
        RealWorldClass::TwitterSim,
        RealWorldClass::WikipediaSim,
        RealWorldClass::LiveJournalSim,
    ] {
        let mut cfg = class.config(9);
        cfg.scale = 11; // shrink for test time
        let g = build_csr(&kronecker(&cfg));
        let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
        check_hybrid(&g, &hw(2, 2), PolicyKind::direction_optimized(), root);
    }
}

#[test]
fn random_partitioning_is_also_correct() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(10, 4)));
    let pg = random_partition(&g, &hw(2, 2), &LayoutOptions::paper(), 99);
    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let mut runner =
        HybridRunner::new(&pg, HybridConfig::default(), Some(&mut sim)).unwrap();
    let root = (0..g.num_vertices as u32).find(|&v| g.degree(v) > 2).unwrap();
    let run = runner.run(root).unwrap();
    assert_eq!(run.depth, reference_depths(&g, root));
    validate_graph500(&g, root, &run.parent, &run.depth).unwrap();
}

#[test]
fn per_activation_comm_mode_is_functionally_identical() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(10, 5)));
    let (pg, _) = specialized_partition(&g, &hw(2, 1), &LayoutOptions::paper());
    let root = (0..g.num_vertices as u32).find(|&v| g.degree(v) > 2).unwrap();

    let run_batched = {
        let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
        let cfg = HybridConfig { comm_mode: CommMode::Batched, ..Default::default() };
        HybridRunner::new(&pg, cfg, Some(&mut sim)).unwrap().run(root).unwrap()
    };
    let run_eager = {
        let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
        let cfg = HybridConfig { comm_mode: CommMode::PerActivation, ..Default::default() };
        HybridRunner::new(&pg, cfg, Some(&mut sim)).unwrap().run(root).unwrap()
    };
    assert_eq!(run_batched.depth, run_eager.depth);
    // But the wire cost differs wildly — that is the ablation's point.
    let b: u64 = run_batched.levels.iter().map(|l| l.comm.push_bytes()).sum();
    let e: u64 = run_eager.levels.iter().map(|l| l.comm.push_bytes()).sum();
    assert!(e > b, "eager {e} should exceed batched {b}");
}

#[test]
fn hybrid_and_baseline_reach_identical_depths() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(11, 6)));
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    let base = baseline_bfs(&g, root, BaselineKind::direction_optimized());
    let (pg, _) = specialized_partition(&g, &hw(2, 2), &LayoutOptions::paper());
    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let mut runner =
        HybridRunner::new(&pg, HybridConfig::default(), Some(&mut sim)).unwrap();
    let run = runner.run(root).unwrap();
    assert_eq!(run.depth, base.depth);
}

#[test]
fn direction_policy_switches_and_reduces_edge_work() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(12, 7)));
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    let (pg, _) = specialized_partition(&g, &hw(2, 0), &LayoutOptions::paper());

    let run = |policy| {
        let mut runner = HybridRunner::<SimAccelerator>::new(
            &pg,
            HybridConfig { policy, ..Default::default() },
            None,
        )
        .unwrap();
        runner.run(root).unwrap()
    };
    let run_do = run(PolicyKind::direction_optimized());
    let run_td = run(PolicyKind::AlwaysTopDown);

    assert!(run_do.levels.iter().any(|l| l.direction == Some(Direction::BottomUp)));
    let edges = |r: &totem_do::bfs::BfsRun| -> u64 {
        r.levels.iter().flat_map(|l| l.pe_work.iter()).map(|w| w.edges_examined).sum()
    };
    assert!(
        edges(&run_do) < edges(&run_td) / 2,
        "D/O {} vs TD {} edges",
        edges(&run_do),
        edges(&run_td)
    );
}

#[test]
fn star_and_path_corner_cases() {
    // Star: one hub, bottom-up trivially finds it.
    let star = build_csr(&EdgeList {
        num_vertices: 64,
        edges: (1..64u32).map(|v| (0, v)).collect(),
    });
    check_hybrid(&star, &hw(2, 1), PolicyKind::direction_optimized(), 0);
    check_hybrid(&star, &hw(2, 1), PolicyKind::direction_optimized(), 63);

    // Path: maximum diameter, frontier of size 1 throughout.
    let path = build_csr(&EdgeList {
        num_vertices: 50,
        edges: (0..49u32).map(|v| (v, v + 1)).collect(),
    });
    check_hybrid(&path, &hw(2, 1), PolicyKind::direction_optimized(), 0);
    check_hybrid(&path, &hw(1, 1), PolicyKind::AlwaysTopDown, 25);
}

#[test]
fn deterministic_across_repeats() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(10, 8)));
    let (pg, _) = specialized_partition(&g, &hw(2, 2), &LayoutOptions::paper());
    let root = (0..g.num_vertices as u32).find(|&v| g.degree(v) > 2).unwrap();
    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let mut runner =
        HybridRunner::new(&pg, HybridConfig::default(), Some(&mut sim)).unwrap();
    let a = runner.run(root).unwrap();
    let b = runner.run(root).unwrap();
    assert_eq!(a.depth, b.depth);
    assert_eq!(a.parent, b.parent);
    let wa: Vec<u64> = a.levels.iter().flat_map(|l| l.pe_work.iter()).map(|w| w.edges_examined).collect();
    let wb: Vec<u64> = b.levels.iter().flat_map(|l| l.pe_work.iter()).map(|w| w.edges_examined).collect();
    assert_eq!(wa, wb, "work counters must be reproducible");
}
