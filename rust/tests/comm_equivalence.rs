//! Comm-equivalence suite: the boundary-compacted outbox/inbox exchange
//! and the adaptive sparse/dense frontier representation are pure
//! *re-encodings* of the engine's communication — traversal outputs
//! (parents, depths, per-level schedule) must stay bit-identical to the
//! pre-refactor full-V dense exchange, at every thread count, while the
//! modeled wire bytes drop to boundary-proportional.
//!
//! The reference below reimplements the engine's pre-refactor semantics
//! directly: per-(source, destination) outgoing bitmaps over the FULL
//! global vertex space, sequential kernels in ascending partition order
//! walking frontiers in ascending gid order, push merge after all
//! kernels, first-candidate-wins everywhere, and the Section 3.1
//! remote-parent contribution fragments resolved at final aggregation —
//! exactly what `engine::comm` + `bfs::hybrid` did with dense buffers.
//! (CPU-only partitionings: the accelerator kernel's scatter-max
//! tie-break is a different, unchanged code path covered by the engine's
//! own cross-mode tests.)

use totem_do::bfs::direction::{CoordinatorView, DirectionPolicy};
use totem_do::bfs::{BfsRun, HybridConfig, HybridRunner, PolicyKind};
use totem_do::engine::state::PARENT_REMOTE;
use totem_do::engine::{CommStats, Direction, ExecutionMode, SimAccelerator};
use totem_do::graph::generator::{erdos_renyi, kronecker, GeneratorConfig};
use totem_do::graph::{build_csr, Csr};
use totem_do::partition::{specialized_partition, HardwareConfig, LayoutOptions, PartitionedGraph};
use totem_do::util::Bitmap;

fn hw(s: usize, g: usize) -> HardwareConfig {
    HardwareConfig { cpu_sockets: s, gpus: g, gpu_mem_bytes: 1 << 22, gpu_max_degree: 32 }
}

/// Pre-refactor dense-exchange reference (see module docs). Returns
/// depths, parents, and the `(frontier size, direction)` level schedule.
fn dense_exchange_reference(
    pg: &PartitionedGraph,
    root: u32,
) -> (Vec<i32>, Vec<i64>, Vec<(u64, Direction)>) {
    let np = pg.parts.len();
    let v = pg.num_vertices;
    let mut depth = vec![-1i32; v];
    let mut parent = vec![-1i64; v];
    let mut visited = vec![false; v];
    let mut current: Vec<Bitmap> = (0..np).map(|_| Bitmap::new(v)).collect();
    let mut next: Vec<Bitmap> = (0..np).map(|_| Bitmap::new(v)).collect();
    // The pre-refactor comm layer: one full-V bitmap per (src, dst) link.
    let mut outgoing: Vec<Vec<Bitmap>> =
        (0..np).map(|_| (0..np).map(|_| Bitmap::new(v)).collect()).collect();
    // Remote-parent contribution fragments: (parent gid, push level),
    // first write wins for the whole run.
    let mut contrib: Vec<Vec<Option<(u32, i32)>>> = (0..np).map(|_| vec![None; v]).collect();
    let mut policy = DirectionPolicy::new(PolicyKind::direction_optimized());

    let rp = pg.owner_of(root);
    depth[root as usize] = 0;
    parent[root as usize] = root as i64;
    visited[root as usize] = true;
    current[rp].set(root as usize);

    let mut levels = Vec::new();
    let mut level = 0u32;
    loop {
        let frontier_size: u64 = current.iter().map(|c| c.count() as u64).sum();
        if frontier_size == 0 {
            break;
        }
        let dir = policy.current();
        levels.push((frontier_size, dir));
        match dir {
            Direction::TopDown => {
                for row in outgoing.iter_mut() {
                    for b in row.iter_mut() {
                        b.clear();
                    }
                }
                // Kernels in ascending partition order, frontiers walked
                // in ascending gid order. Immediate application equals the
                // engine's deferred first-candidate-wins barrier merge:
                // only the owner's own kernel activates its vertices
                // during the kernel phase, and the first proposer in
                // whole-queue order wins either way.
                for p in 0..np {
                    let part = &pg.parts[p];
                    for u in current[p].iter_ones() {
                        let li = pg.local_of(u as u32);
                        for &w in part.neighbours(li) {
                            let q = pg.owner_of(w);
                            let wi = w as usize;
                            if q == p {
                                if !visited[wi] {
                                    visited[wi] = true;
                                    depth[wi] = (level + 1) as i32;
                                    parent[wi] = u as i64;
                                    next[p].set(wi);
                                }
                            } else {
                                outgoing[p][q].set(wi);
                                if contrib[p][wi].is_none() {
                                    contrib[p][wi] = Some((u as u32, level as i32));
                                }
                            }
                        }
                    }
                }
                // Push merge after all kernels: ascending destination, OR
                // of all sources, ascending gid, already-visited loses.
                for q in 0..np {
                    let mut incoming = Bitmap::new(v);
                    for p in 0..np {
                        if p != q {
                            incoming.or_with(&outgoing[p][q]);
                        }
                    }
                    for wi in incoming.iter_ones() {
                        if !visited[wi] {
                            visited[wi] = true;
                            depth[wi] = (level + 1) as i32;
                            parent[wi] = PARENT_REMOTE;
                            next[q].set(wi);
                        }
                    }
                }
            }
            Direction::BottomUp => {
                let mut gf = Bitmap::new(v);
                for c in &current {
                    gf.or_with(c);
                }
                for p in 0..np {
                    let part = &pg.parts[p];
                    for li in 0..part.scan_limit {
                        let gid = part.gids[li] as usize;
                        if visited[gid] {
                            continue;
                        }
                        for &w in part.neighbours(li) {
                            if gf.get(w as usize) {
                                visited[gid] = true;
                                depth[gid] = (level + 1) as i32;
                                parent[gid] = w as i64;
                                next[p].set(gid);
                                break;
                            }
                        }
                    }
                }
            }
        }
        for p in 0..np {
            std::mem::swap(&mut current[p], &mut next[p]);
            next[p].clear();
        }
        // The coordinator's strictly-local switch decision (partition 0).
        let part0 = &pg.parts[0];
        let mut frontier_out = 0u64;
        for u in current[0].iter_ones() {
            frontier_out += part0.degree(pg.local_of(u as u32)) as u64;
        }
        let mut unexplored = 0u64;
        for li in 0..part0.num_vertices() {
            if !visited[part0.gids[li] as usize] {
                unexplored += part0.degree(li) as u64;
            }
        }
        policy.advance(CoordinatorView {
            frontier_out_edges: frontier_out,
            unexplored_edges: unexplored,
            ..Default::default()
        });
        level += 1;
    }
    // Final aggregation: lowest partition id holding a contribution
    // pushed at depth-1 resolves the remote parent.
    for wi in 0..v {
        if parent[wi] == PARENT_REMOTE {
            let want = depth[wi] - 1;
            let winner = (0..np)
                .find_map(|p| contrib[p][wi].filter(|&(_, lvl)| lvl == want))
                .expect("remote vertex without a matching contribution");
            parent[wi] = winner.0 as i64;
        }
    }
    (depth, parent, levels)
}

fn run_engine(pg: &PartitionedGraph, gpus: usize, root: u32, threads: usize) -> BfsRun {
    let cfg = HybridConfig {
        policy: PolicyKind::direction_optimized(),
        exec: ExecutionMode::from_threads(threads),
        ..Default::default()
    };
    let mut sim = SimAccelerator::new(pg.parts.len(), pg.num_vertices);
    let accel = if gpus > 0 { Some(&mut sim) } else { None };
    let mut runner = HybridRunner::new(pg, cfg, accel).unwrap();
    runner.run(root).unwrap()
}

fn test_graphs() -> Vec<(Csr, &'static str)> {
    vec![
        (build_csr(&kronecker(&GeneratorConfig::graph500(9, 2))), "rmat-9"),
        (build_csr(&erdos_renyi(1500, 6000, 7)), "er-1500"),
    ]
}

#[test]
fn compacted_exchange_matches_dense_reference_at_threads_1_and_4() {
    for (g, name) in test_graphs() {
        for sockets in [2usize, 3] {
            let (pg, _) = specialized_partition(&g, &hw(sockets, 0), &LayoutOptions::paper());
            let hub = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
            for root in [hub, 0, (g.num_vertices / 2) as u32] {
                let (rd, rp, rl) = dense_exchange_reference(&pg, root);
                for threads in [1usize, 4] {
                    let run = run_engine(&pg, 0, root, threads);
                    assert_eq!(run.depth, rd, "{name} {sockets}S root {root} t{threads}: depths");
                    assert_eq!(run.parent, rp, "{name} {sockets}S root {root} t{threads}: parents");
                    let schedule: Vec<(u64, Direction)> = run
                        .levels
                        .iter()
                        .map(|l| (l.frontier_size, l.direction.unwrap()))
                        .collect();
                    assert_eq!(schedule, rl, "{name} {sockets}S root {root} t{threads}: levels");
                }
            }
        }
    }
}

#[test]
fn outputs_identical_across_thread_ladder_with_gpus() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(10, 5)));
    let (pg, _) = specialized_partition(&g, &hw(2, 2), &LayoutOptions::paper());
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    let base = run_engine(&pg, 2, root, 1);
    for threads in [2usize, 4, 8] {
        let run = run_engine(&pg, 2, root, threads);
        assert_eq!(base.depth, run.depth, "t{threads}");
        assert_eq!(base.parent, run.parent, "t{threads}");
        // LevelStats equality covers per-PE work counters AND the comm
        // stats — the boundary-compacted byte accounting is thread-count
        // invariant too.
        assert_eq!(base.levels, run.levels, "t{threads}");
        assert_eq!(base.aggregation_bytes, run.aggregation_bytes, "t{threads}");
    }
}

#[test]
fn compacted_wire_bytes_sit_strictly_below_the_dense_scheme() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(11, 3)));
    let (pg, _) = specialized_partition(&g, &hw(2, 2), &LayoutOptions::paper());
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    let run = run_engine(&pg, 2, root, 1);
    let mut total = CommStats::default();
    for l in &run.levels {
        total.add(&l.comm);
    }
    assert!(total.total_bytes() > 0, "traversal exercised the exchange");
    assert!(
        total.total_bytes() < total.dense_equiv_bytes,
        "boundary-compacted bytes ({}) must sit strictly below the full-V scheme ({})",
        total.total_bytes(),
        total.dense_equiv_bytes
    );
    // Per-level sanity: compaction can only reduce, never inflate.
    for l in &run.levels {
        assert!(l.comm.total_bytes() <= l.comm.dense_equiv_bytes, "level {}", l.level);
    }
}
