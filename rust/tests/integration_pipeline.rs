//! Pipeline integration: generate -> save -> load -> partition -> BFS ->
//! metrics/timing/energy, exercising the same paths the CLI and benches
//! use (no artifacts needed: Sim accelerator).

use totem_do::bfs::{baseline_bfs, BaselineKind, HybridConfig, HybridRunner};
use totem_do::engine::SimAccelerator;
use totem_do::graph::generator::{kronecker, GeneratorConfig};
use totem_do::graph::{build_csr, io};
use totem_do::metrics;
use totem_do::partition::{specialized_partition, HardwareConfig, LayoutOptions};
use totem_do::runtime::{mteps_per_watt, DeviceModel, EnergyModel};

fn hw(s: usize, g: usize) -> HardwareConfig {
    HardwareConfig { cpu_sockets: s, gpus: g, gpu_mem_bytes: 1 << 26, gpu_max_degree: 32 }
}

#[test]
fn generate_save_load_partition_bfs_roundtrip() {
    let el = kronecker(&GeneratorConfig::graph500(11, 17));
    let path = std::env::temp_dir().join(format!("totem_pipe_{}.bin", std::process::id()));
    io::save_binary(&el, &path).unwrap();
    let el2 = io::load_binary(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(el.edges, el2.edges);

    let g = build_csr(&el2);
    let (pg, plan) = specialized_partition(&g, &hw(2, 2), &LayoutOptions::paper());
    assert!(plan.gpu_vertices > 0);

    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let mut runner = HybridRunner::new(&pg, HybridConfig::default(), Some(&mut sim)).unwrap();

    let roots = metrics::sample_roots(g.num_vertices, |v| g.degree(v) as usize, 8, 5);
    assert_eq!(roots.len(), 8);

    let device = DeviceModel::default();
    let energy = EnergyModel::default();
    let mut teps = Vec::new();
    for &root in &roots {
        let run = runner.run(root).unwrap();
        let t = device.attribute(&run, &pg, false);
        let e = energy.energy(&t, &pg);
        teps.push(metrics::teps(run.traversed_edges(), t.total));
        assert!(mteps_per_watt(run.traversed_edges(), &e) > 0.0);
    }
    let summary = metrics::summarize(&teps, 1.0);
    assert_eq!(summary.runs, 8);
    assert!(summary.harmonic_teps > 0.0);
    assert!(summary.harmonic_teps <= summary.mean_teps + 1e-9);
}

#[test]
fn campaign_roots_avoid_singletons_and_runs_are_independent() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(10, 23)));
    let roots = metrics::sample_roots(g.num_vertices, |v| g.degree(v) as usize, 16, 7);
    assert!(roots.iter().all(|&r| g.degree(r) > 0));

    let (pg, _) = specialized_partition(&g, &hw(1, 1), &LayoutOptions::paper());
    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let mut runner = HybridRunner::new(&pg, HybridConfig::default(), Some(&mut sim)).unwrap();
    // Same root run first, middle, and last must give identical results.
    let first = runner.run(roots[0]).unwrap();
    for &r in &roots[1..] {
        runner.run(r).unwrap();
    }
    let again = runner.run(roots[0]).unwrap();
    assert_eq!(first.depth, again.depth);
    assert_eq!(first.parent, again.parent);
}

#[test]
fn modeled_speedup_shape_hybrid_vs_cpu_only() {
    // The paper's headline shape at bench scale, via the pipeline API:
    // 2S2G beats 2S on a skewed graph; the gain is concentrated in
    // bottom-up levels (Fig 4). Scale 16 keeps test time low while being
    // past the PCIe-latency crossover.
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(16, 29)));
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    let device = DeviceModel::default();

    let t_cpu = {
        let (pg, _) = specialized_partition(&g, &hw(2, 0), &LayoutOptions::paper());
        let mut runner =
            HybridRunner::<SimAccelerator>::new(&pg, HybridConfig::default(), None).unwrap();
        let run = runner.run(root).unwrap();
        device.attribute(&run, &pg, false).total
    };
    let t_hyb = {
        let (pg, _) = specialized_partition(&g, &hw(2, 2), &LayoutOptions::paper());
        let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
        let mut runner =
            HybridRunner::new(&pg, HybridConfig::default(), Some(&mut sim)).unwrap();
        let run = runner.run(root).unwrap();
        device.attribute(&run, &pg, false).total
    };
    assert!(
        t_hyb < t_cpu,
        "2S2G ({:.1} us) should beat 2S ({:.1} us)",
        t_hyb * 1e6,
        t_cpu * 1e6
    );
}

#[test]
fn baseline_comparators_run_through_device_model() {
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(11, 31)));
    let root = (0..g.num_vertices as u32).find(|&v| g.degree(v) > 2).unwrap();
    let device = DeviceModel::default();
    let do_run = baseline_bfs(&g, root, BaselineKind::direction_optimized());
    let td_run = baseline_bfs(&g, root, BaselineKind::TopDown);
    let t_do = device.attribute_baseline(&do_run, 2, false).total;
    let t_td = device.attribute_baseline(&td_run, 2, false).total;
    let t_naive = device.attribute_baseline(&td_run, 2, true).total;
    // Table 1 column ordering: Naive < TD-optimized < D/O (in rate).
    assert!(t_do < t_td, "D/O {t_do} should beat TD {t_td}");
    assert!(t_td < t_naive, "optimized TD should beat naive TD");
}
