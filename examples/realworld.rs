//! Real-world-class workloads (the paper's Table 1 scenario): compare
//! top-down vs direction-optimized, CPU-only vs hybrid, on the
//! twitter-sim / wiki-sim / lj-sim analogs.
//!
//!     cargo run --release --example realworld

use anyhow::Result;

use totem_do::bench_support as bs;
use totem_do::bfs::PolicyKind;
use totem_do::graph::generator::RealWorldClass;
use totem_do::util::tables::{fmt_teps, Table};

fn main() -> Result<()> {
    let mut t = Table::new(vec!["graph", "algorithm", "2S", "2S2G", "hybrid gain"]);
    for class in [
        RealWorldClass::TwitterSim,
        RealWorldClass::WikipediaSim,
        RealWorldClass::LiveJournalSim,
    ] {
        let g = bs::realworld_graph(class, 42);
        let roots = bs::roots_for(&g, bs::bench_roots(), 11);
        for (pol, label) in [
            (PolicyKind::AlwaysTopDown, "Top-Down"),
            (PolicyKind::direction_optimized(), "Direction-Optimized"),
        ] {
            let cpu = bs::run_config(&g, "2S", pol, &roots)?;
            let hyb = bs::run_config(&g, "2S2G", pol, &roots)?;
            t.row(vec![
                class.name().to_string(),
                label.to_string(),
                fmt_teps(cpu.teps),
                fmt_teps(hyb.teps),
                format!("{:.2}x", hyb.teps / cpu.teps),
            ]);
        }
    }
    t.print();
    println!("\n(modeled on the paper's testbed; see DESIGN.md Section 6 for the device model)");
    Ok(())
}
