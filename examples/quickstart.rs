//! Quickstart: build a small scale-free graph, partition it for a hybrid
//! 1-socket + 1-accelerator machine, run one direction-optimized BFS, and
//! print the per-level story.
//!
//!     cargo run --release --example quickstart

use anyhow::Result;

use totem_do::bfs::{validate_graph500, HybridConfig, HybridRunner};
use totem_do::engine::SimAccelerator;
use totem_do::graph::generator::{kronecker, GeneratorConfig};
use totem_do::graph::build_csr;
use totem_do::partition::{specialized_partition, HardwareConfig, LayoutOptions};
use totem_do::runtime::{DeviceModel, EnergyModel};
use totem_do::util::tables::{fmt_time, Table};

fn main() -> Result<()> {
    // 1. A Graph500-style Kronecker graph: 2^14 vertices, edge factor 16.
    let g = build_csr(&kronecker(&GeneratorConfig::graph500(14, 42)));
    println!(
        "graph: {} vertices, {} undirected edges",
        g.num_vertices,
        g.num_undirected_edges()
    );

    // 2. Specialized partitioning (paper Section 3.2): low-degree vertices
    //    go to the accelerator, hubs stay on the CPU socket.
    let hw = HardwareConfig { cpu_sockets: 1, gpus: 1, gpu_mem_bytes: 64 << 20, gpu_max_degree: 32 };
    let (pg, plan) = specialized_partition(&g, &hw, &LayoutOptions::paper());
    println!(
        "partitioning: degree threshold {}, {}/{} non-singleton vertices on the accelerator",
        plan.degree_threshold, plan.gpu_vertices, plan.non_singleton
    );

    // 3. One direction-optimized BFS from the top hub. The SimAccelerator
    //    is the bit-exact mirror of the AOT Pallas kernels; swap in
    //    `PjrtAccelerator::new(&default_artifact_dir(), g.num_vertices)?`
    //    after `make artifacts` for the real AOT path.
    let root = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    let mut sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
    let mut runner = HybridRunner::new(&pg, HybridConfig::default(), Some(&mut sim))?;
    let run = runner.run(root)?;
    validate_graph500(&g, root, &run.parent, &run.depth).map_err(anyhow::Error::msg)?;

    // 4. The per-level story (paper Fig 1/4): time attributed on the
    //    paper's testbed by the device model.
    let timing = DeviceModel::default().attribute(&run, &pg, false);
    let mut t = Table::new(vec!["level", "direction", "frontier", "avg deg", "CPU", "GPU", "comm"]);
    for (ls, lt) in run.levels.iter().zip(&timing.levels) {
        t.row(vec![
            ls.level.to_string(),
            ls.direction.unwrap().label().to_string(),
            ls.frontier_size.to_string(),
            format!("{:.1}", ls.avg_frontier_degree()),
            fmt_time(lt.pe_time[0]),
            fmt_time(lt.pe_time[1]),
            fmt_time(lt.comm_time),
        ]);
    }
    t.print();

    let e = EnergyModel::default().energy(&timing, &pg);
    println!(
        "\nreached {} vertices ({} edges) | modeled {} | {:.0} W avg | host wall {}",
        run.reached_vertices,
        run.traversed_edges(),
        fmt_time(timing.total),
        e.avg_watts,
        fmt_time(run.wall.as_secs_f64()),
    );
    println!("BFS tree validated against the Graph500 checks.");
    Ok(())
}
