//! Energy report (paper Section 4.3): MTEPS/W across hardware configs,
//! including the paper's "add a GPU beats adding a CPU" comparison.
//!
//!     cargo run --release --example energy_report

use anyhow::Result;

use totem_do::bench_support as bs;
use totem_do::bfs::PolicyKind;
use totem_do::util::tables::{fmt_teps, Table};

fn main() -> Result<()> {
    let g = bs::kron_graph(bs::bench_scale(), 42);
    let roots = bs::roots_for(&g, bs::bench_roots(), 13);
    println!(
        "workload: kron scale {} ({} vertices, {} undirected edges), {} roots\n",
        bs::bench_scale(),
        g.num_vertices,
        g.num_undirected_edges(),
        roots.len()
    );

    let mut t = Table::new(vec!["config", "TEPS (modeled)", "MTEPS/W", "vs 2S"]);
    let base = bs::run_config(&g, "2S", PolicyKind::direction_optimized(), &roots)?;
    for label in ["1S", "2S", "1S1G", "2S1G", "1S2G", "2S2G", "4S"] {
        let r = bs::run_config(&g, label, PolicyKind::direction_optimized(), &roots)?;
        t.row(vec![
            label.to_string(),
            fmt_teps(r.teps),
            format!("{:.2}", r.mteps_per_watt),
            format!("{:.2}x", r.mteps_per_watt / base.mteps_per_watt),
        ]);
    }
    t.print();

    println!("\nThe paper's Section 4.3 claims, checked on this workload:");
    let s2g1 = bs::run_config(&g, "2S1G", PolicyKind::direction_optimized(), &roots)?;
    let s4 = bs::run_config(&g, "4S", PolicyKind::direction_optimized(), &roots)?;
    let s2g2 = bs::run_config(&g, "2S2G", PolicyKind::direction_optimized(), &roots)?;
    println!(
        "  add a GPU vs add 2 CPUs: 2S1G {:.2} MTEPS/W vs 4S {:.2} MTEPS/W -> {}",
        s2g1.mteps_per_watt,
        s4.mteps_per_watt,
        if s2g1.mteps_per_watt > s4.mteps_per_watt { "GPU wins (paper agrees)" } else { "CPU wins (paper disagrees)" }
    );
    println!(
        "  hybrid vs CPU-only efficiency: 2S2G/2S = {:.2}x (paper: ~2x)",
        s2g2.mteps_per_watt / base.mteps_per_watt
    );
    Ok(())
}
