//! Partition explorer: how the specialized partitioner responds to
//! accelerator memory budgets and width ceilings, vs random placement
//! (paper Sections 3.2 / 4.1).
//!
//!     cargo run --release --example partition_explorer

use anyhow::Result;

use totem_do::bench_support as bs;
use totem_do::graph::stats::degree_stats;
use totem_do::partition::{
    random_partition, specialized_partition, HardwareConfig, LayoutOptions,
};
use totem_do::util::tables::Table;

fn main() -> Result<()> {
    let g = bs::kron_graph(16, 42);
    let s = degree_stats(&g);
    println!(
        "graph: {} vertices ({} singletons), {} undirected edges, max degree {}",
        s.num_vertices,
        s.num_singletons,
        g.num_undirected_edges(),
        s.max_degree
    );

    println!("\n-- accelerator memory sweep (2 GPUs, width ceiling 32) --");
    let mut t = Table::new(vec![
        "GPU mem (MiB)",
        "deg threshold",
        "vertex share",
        "edge share",
        "ELL bytes/GPU",
    ]);
    for mem_mb in [1u64, 4, 16, 64, 256] {
        let hw = HardwareConfig {
            cpu_sockets: 2,
            gpus: 2,
            gpu_mem_bytes: mem_mb << 20,
            gpu_max_degree: 32,
        };
        let (pg, plan) = specialized_partition(&g, &hw, &LayoutOptions::paper());
        let max_ell = pg
            .parts
            .iter()
            .filter(|p| p.kind.is_gpu())
            .map(|p| p.ell_footprint_bytes())
            .max()
            .unwrap_or(0);
        t.row(vec![
            mem_mb.to_string(),
            plan.degree_threshold.to_string(),
            format!("{:.1}%", pg.gpu_vertex_share(&g) * 100.0),
            format!("{:.1}%", pg.gpu_edge_share() * 100.0),
            max_ell.to_string(),
        ]);
    }
    t.print();

    println!("\n-- width-ceiling sweep (2 GPUs, 256 MiB) --");
    let mut t = Table::new(vec!["max degree", "deg threshold", "vertex share", "edge share"]);
    for maxd in [4usize, 8, 16, 32] {
        let hw = HardwareConfig {
            cpu_sockets: 2,
            gpus: 2,
            gpu_mem_bytes: 256 << 20,
            gpu_max_degree: maxd,
        };
        let (pg, plan) = specialized_partition(&g, &hw, &LayoutOptions::paper());
        t.row(vec![
            maxd.to_string(),
            plan.degree_threshold.to_string(),
            format!("{:.1}%", pg.gpu_vertex_share(&g) * 100.0),
            format!("{:.1}%", pg.gpu_edge_share() * 100.0),
        ]);
    }
    t.print();

    println!("\n-- specialized vs random placement (same constraints) --");
    let hw = HardwareConfig { cpu_sockets: 2, gpus: 2, gpu_mem_bytes: 64 << 20, gpu_max_degree: 32 };
    let (spec, _) = specialized_partition(&g, &hw, &LayoutOptions::paper());
    let rand = random_partition(&g, &hw, &LayoutOptions::paper(), 7);
    let mut t = Table::new(vec!["strategy", "vertex share", "edge share", "hub location"]);
    let hub = (0..g.num_vertices as u32).max_by_key(|&v| g.degree(v)).unwrap();
    for (name, pg) in [("specialized", &spec), ("random", &rand)] {
        t.row(vec![
            name.to_string(),
            format!("{:.1}%", pg.gpu_vertex_share(&g) * 100.0),
            format!("{:.1}%", pg.gpu_edge_share() * 100.0),
            pg.parts[pg.owner_of(hub)].kind.label(),
        ]);
    }
    t.print();
    println!("\nspecialized placement puts many vertices but few edges on the");
    println!("accelerators — exactly the bottom-up workload (paper Section 3.2).");
    Ok(())
}
