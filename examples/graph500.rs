//! End-to-end Graph500-style campaign — the repository's full-system
//! driver, now running through the resident multi-query **service layer**:
//! the graph is ingested and partitioned once into a [`GraphRegistry`],
//! the 64 searches flow through the batched query scheduler, and
//! traversal state is recycled by the per-graph state pool (O(touched)
//! resets between searches). Per-query results are bit-identical to
//! standalone runs — every search is still Graph500-validated.
//!
//!     cargo run --release --example graph500 [-- scale [config] [roots]]
//!
//! Defaults: scale 18, config 2S2G, 64 roots. Reported TEPS is the
//! **harmonic mean** over searches, as the Graph500 specification
//! requires (the arithmetic mean overstates a campaign dominated by a few
//! fast searches and is deliberately not reported).

// Still on the deprecated BFS-only `run_batch` wrapper for one release —
// this example is the shim's named consumer; it migrates to
// `run_requests` when the shim is removed.
#![allow(deprecated)]

// Bench/harness timing is host wall-clock measurement by definition.
#![allow(clippy::disallowed_methods)]

use anyhow::{anyhow, Result};

use totem_do::bench_support as bs;
use totem_do::bfs::validate_graph500;
use totem_do::metrics;
use totem_do::partition::{specialized_partition_par, LayoutOptions};
use totem_do::runtime::{mteps_per_watt, DeviceModel, EnergyModel};
use totem_do::service::{run_batch, BatchOptions, GraphRegistry, ResidentGraph, SchedulePolicy};
use totem_do::util::tables::{fmt_teps, fmt_time, Table};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: u32 = args.first().and_then(|s| s.parse().ok()).unwrap_or(18);
    let config = args.get(1).cloned().unwrap_or_else(|| "2S2G".to_string());
    let nroots: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);
    let threads = bs::bench_threads();

    println!("== Graph500-style campaign: scale {scale}, {config}, {nroots} roots ==");
    let t_gen = std::time::Instant::now();
    let g = bs::kron_graph(scale, 42);
    println!(
        "generation+construction: {} ({} vertices, {} undirected edges)",
        fmt_time(t_gen.elapsed().as_secs_f64()),
        g.num_vertices,
        g.num_undirected_edges()
    );

    // ---- registry: ingest/partition once, resident for the campaign ----
    let hw = bs::hardware(&config);
    let (pg, plan) = specialized_partition_par(&g, &hw, &LayoutOptions::paper(), threads);
    println!(
        "partitioning: threshold deg<={}, accelerator share {:.1}% of non-singletons",
        plan.degree_threshold,
        100.0 * plan.gpu_vertices as f64 / plan.non_singleton.max(1) as f64
    );
    let registry = GraphRegistry::new();
    let rg = registry.insert(ResidentGraph::from_partitioned(
        &format!("kron-scale{scale}"),
        g,
        &hw,
        pg,
    ))?;
    if hw.gpus > 0 {
        println!(
            "accelerator: shared resident SimAccelerator device image \
             (bit-exact Pallas-kernel mirror; sessions share the SELL uploads)"
        );
    }

    // ---- the 64-search campaign through the batched scheduler ----
    // Latency schedule: searches run one at a time with the whole thread
    // budget, as the Graph500 methodology times them — per-search wall
    // clock stays free of co-running-query contention (and comparable to
    // pre-service campaign records). Residency + state recycling still
    // come from the registry/pool; `benches/throughput_service.rs` is the
    // surface that measures the Throughput schedule.
    let roots = bs::roots_for(&rg.csr, nroots, 7);
    let opts = BatchOptions {
        threads,
        policy: SchedulePolicy::Latency,
        max_concurrency: 1,
        ..Default::default()
    };
    let device = DeviceModel::default();
    let energy = EnergyModel::default();
    let t0 = std::time::Instant::now();
    let outcomes = run_batch(&rg, &roots, &opts)?;
    let wall_total = t0.elapsed().as_secs_f64();

    let mut teps_model = Vec::new();
    let mut teps_wall = Vec::new();
    let mut latencies = Vec::new();
    let mut eff = Vec::new();
    for (i, outcome) in outcomes.iter().enumerate() {
        let run = outcome
            .run()
            .ok_or_else(|| anyhow!("query {i} (root {}) failed", roots[i]))?;
        validate_graph500(&rg.csr, run.root, &run.parent, &run.depth)
            .map_err(anyhow::Error::msg)?;
        let t = device.attribute(run, &rg.pg, false);
        let e = energy.energy(&t, &rg.pg);
        teps_model.push(metrics::teps(run.traversed_edges(), t.total));
        teps_wall.push(metrics::teps(run.traversed_edges(), run.wall.as_secs_f64()));
        latencies.push(t.total);
        eff.push(mteps_per_watt(run.traversed_edges(), &e));
        if (i + 1) % 16 == 0 {
            println!("  {}/{} searches validated...", i + 1, outcomes.len());
        }
    }

    let lat = metrics::latency_summary(&latencies);
    let pool = rg.states.stats();
    let mut t = Table::new(vec!["metric", "modeled (paper testbed)", "measured (this host)"]);
    t.row(vec![
        "harmonic TEPS".to_string(),
        fmt_teps(metrics::harmonic_mean(&teps_model)),
        fmt_teps(metrics::harmonic_mean(&teps_wall)),
    ]);
    t.row(vec![
        "latency p50 / p99".to_string(),
        format!("{} / {}", fmt_time(lat.p50), fmt_time(lat.p99)),
        "-".to_string(),
    ]);
    t.row(vec![
        "GreenGraph500".to_string(),
        format!("{:.2} MTEPS/W", metrics::harmonic_mean(&eff)),
        "-".to_string(),
    ]);
    t.row(vec![
        "campaign throughput".to_string(),
        "-".to_string(),
        format!("{:.2} queries/s", outcomes.len() as f64 / wall_total.max(1e-12)),
    ]);
    t.print();
    println!(
        "\nall {} searches passed the Graph500 validation checks; campaign wall time {}; \
         {} searches served from {} pooled traversal state(s) (O(touched) recycle)",
        outcomes.len(),
        fmt_time(wall_total),
        outcomes.len(),
        pool.created
    );
    bs::kv("graph500", &[
        ("scale", scale.to_string()),
        ("config", config.clone()),
        ("roots", outcomes.len().to_string()),
        ("threads", threads.to_string()),
        ("batch", opts.max_concurrency.to_string()),
        ("harmonic_teps", format!("{:.3e}", metrics::harmonic_mean(&teps_model))),
        ("wall_harmonic_teps", format!("{:.3e}", metrics::harmonic_mean(&teps_wall))),
        ("latency_p50_s", format!("{:.3e}", lat.p50)),
        ("latency_p99_s", format!("{:.3e}", lat.p99)),
        ("mteps_per_watt", format!("{:.3}", metrics::harmonic_mean(&eff))),
    ]);
    Ok(())
}
