//! End-to-end Graph500-style campaign — the repository's full-system
//! driver: Kronecker generation, specialized partitioning, the AOT Pallas
//! kernels via PJRT (when `make artifacts` has run), 64 validated searches,
//! harmonic-mean TEPS and GreenGraph500 MTEPS/W.
//!
//!     cargo run --release --example graph500 [-- scale [config] [roots]]
//!
//! Defaults: scale 18, config 2S2G, 64 roots. Exercises all three layers:
//! the Rust coordinator, the JAX-lowered HLO, and the PJRT runtime.

use anyhow::Result;

use totem_do::bench_support as bs;
use totem_do::bfs::{validate_graph500, HybridConfig, HybridRunner};
use totem_do::engine::{Accelerator, SimAccelerator};
use totem_do::metrics;
use totem_do::partition::{specialized_partition, LayoutOptions};
use totem_do::runtime::{
    default_artifact_dir, mteps_per_watt, DeviceModel, EnergyModel, PjrtAccelerator,
};
use totem_do::util::tables::{fmt_teps, fmt_time, Table};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: u32 = args.first().and_then(|s| s.parse().ok()).unwrap_or(18);
    let config = args.get(1).cloned().unwrap_or_else(|| "2S2G".to_string());
    let nroots: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);

    println!("== Graph500-style campaign: scale {scale}, {config}, {nroots} roots ==");
    let t_gen = std::time::Instant::now();
    let g = bs::kron_graph(scale, 42);
    println!(
        "generation+construction: {} ({} vertices, {} undirected edges)",
        fmt_time(t_gen.elapsed().as_secs_f64()),
        g.num_vertices,
        g.num_undirected_edges()
    );

    let hw = bs::hardware(&config);
    let (pg, plan) = specialized_partition(&g, &hw, &LayoutOptions::paper());
    println!(
        "partitioning: threshold deg<={}, accelerator share {:.1}% of non-singletons",
        plan.degree_threshold,
        100.0 * plan.gpu_vertices as f64 / plan.non_singleton.max(1) as f64
    );

    // Accelerator: PJRT artifacts when available, Sim mirror otherwise.
    let mut sim;
    let mut pjrt;
    // This example is the flagship end-to-end driver: it prefers the real
    // AOT/PJRT path whenever artifacts exist (TOTEM_DO_BENCH_ACCEL=sim
    // overrides for a quick run).
    let prefer_pjrt = std::env::var("TOTEM_DO_BENCH_ACCEL").as_deref() != Ok("sim")
        && default_artifact_dir().join("manifest.txt").exists();
    let accel: Option<&mut dyn Accelerator> = if hw.gpus == 0 {
        None
    } else if prefer_pjrt {
        println!("accelerator: PJRT (AOT artifacts from {})", default_artifact_dir().display());
        pjrt = PjrtAccelerator::new(&default_artifact_dir(), g.num_vertices)?;
        Some(&mut pjrt)
    } else {
        println!("accelerator: Sim mirror (run `make artifacts` for the PJRT path)");
        sim = SimAccelerator::new(pg.parts.len(), g.num_vertices);
        Some(&mut sim)
    };

    let roots = bs::roots_for(&g, nroots, 7);
    let device = DeviceModel::default();
    let energy = EnergyModel::default();
    let mut runner = HybridRunner::new(&pg, HybridConfig::default(), accel)?;

    let mut teps_model = Vec::new();
    let mut teps_wall = Vec::new();
    let mut eff = Vec::new();
    let t0 = std::time::Instant::now();
    for (i, &root) in roots.iter().enumerate() {
        let run = runner.run(root)?;
        validate_graph500(&g, root, &run.parent, &run.depth).map_err(anyhow::Error::msg)?;
        let t = device.attribute(&run, &pg, false);
        let e = energy.energy(&t, &pg);
        teps_model.push(metrics::teps(run.traversed_edges(), t.total));
        teps_wall.push(metrics::teps(run.traversed_edges(), run.wall.as_secs_f64()));
        eff.push(mteps_per_watt(run.traversed_edges(), &e));
        if (i + 1) % 16 == 0 {
            println!("  {}/{} searches validated...", i + 1, roots.len());
        }
    }
    let wall_total = t0.elapsed().as_secs_f64();

    let sm = metrics::summarize(&teps_model, wall_total);
    let sw = metrics::summarize(&teps_wall, wall_total);
    let mut t = Table::new(vec!["metric", "modeled (paper testbed)", "measured (this host)"]);
    t.row(vec!["harmonic TEPS".to_string(), fmt_teps(sm.harmonic_teps), fmt_teps(sw.harmonic_teps)]);
    t.row(vec!["mean TEPS".to_string(), fmt_teps(sm.mean_teps), fmt_teps(sw.mean_teps)]);
    t.row(vec!["min/max TEPS".to_string(),
        format!("{} / {}", fmt_teps(sm.min_teps), fmt_teps(sm.max_teps)),
        format!("{} / {}", fmt_teps(sw.min_teps), fmt_teps(sw.max_teps))]);
    t.row(vec![
        "GreenGraph500".to_string(),
        format!("{:.2} MTEPS/W", metrics::harmonic_mean(&eff)),
        "-".to_string(),
    ]);
    t.print();
    println!(
        "\nall {} searches passed the Graph500 validation checks; campaign wall time {}",
        roots.len(),
        fmt_time(wall_total)
    );
    bs::kv("graph500", &[
        ("scale", scale.to_string()),
        ("config", config.clone()),
        ("roots", roots.len().to_string()),
        ("harmonic_teps", format!("{:.3e}", sm.harmonic_teps)),
        ("wall_harmonic_teps", format!("{:.3e}", sw.harmonic_teps)),
        ("mteps_per_watt", format!("{:.3}", metrics::harmonic_mean(&eff))),
    ]);
    Ok(())
}
