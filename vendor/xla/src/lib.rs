//! Compile-time stub of the PJRT/XLA binding surface used by
//! `totem_do::runtime::pjrt` (see `vendor/README.md`).
//!
//! The real bindings are not available in this offline environment, so
//! every type here is API-compatible but inert: [`PjRtClient::cpu`] (the
//! single entry point to the runtime) returns an error, which the caller
//! surfaces as a clean "PJRT runtime not available" failure at accelerator
//! construction time. Nothing downstream of a constructed client is
//! reachable, so those methods are `unreachable!` bodies that exist purely
//! to type-check the production code path.

use std::fmt;

/// Error type returned by every stub entry point.
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn stub_err(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime not available (offline xla stub; swap in the real \
         bindings via rust/Cargo.toml to enable the PJRT accelerator path)"
    ))
}

/// A PJRT client handle. Unconstructible through the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The CPU PJRT client. Always fails in the stub — this is the single
    /// gate through which the production path discovers the runtime is
    /// absent.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(stub_err("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unreachable!("xla stub: no client can exist")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unreachable!("xla stub: no client can exist")
    }
}

/// Parsed HLO module. Unconstructible through the stub.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(stub_err("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        unreachable!("xla stub: no HloModuleProto can exist")
    }
}

/// A compiled executable. Unconstructible through the stub.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed buffer arguments; returns per-device,
    /// per-output buffers.
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unreachable!("xla stub: no executable can exist")
    }
}

/// A device-resident buffer. Unconstructible through the stub.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unreachable!("xla stub: no buffer can exist")
    }
}

/// A host-side literal value. Unconstructible through the stub.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unreachable!("xla stub: no literal can exist")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unreachable!("xla stub: no literal can exist")
    }

    pub fn get_first_element<T>(&self) -> Result<T, Error> {
        unreachable!("xla stub: no literal can exist")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_entry_point_reports_stub_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("PJRT runtime not available"), "{msg}");
    }

    #[test]
    fn hlo_parse_reports_stub_cleanly() {
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
    }
}
