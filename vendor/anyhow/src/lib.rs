//! Offline, API-compatible subset of the `anyhow` crate (the real crate is
//! not vendored in this environment — see `vendor/README.md`).
//!
//! Implements exactly the surface this workspace uses:
//!
//! * [`Error`]: an erased error with a context chain. `{}` prints the
//!   outermost message; `{:?}` prints the chain as `Caused by:` lines.
//! * [`Result`]: `std::result::Result` defaulted to [`Error`].
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! ```
//! use anyhow::{ensure, Context, Result};
//!
//! fn parse(s: &str) -> Result<u32> {
//!     let n: u32 = s.parse().with_context(|| format!("bad number {s:?}"))?;
//!     ensure!(n > 0, "expected a positive number, got {n}");
//!     Ok(n)
//! }
//!
//! assert_eq!(parse("7").unwrap(), 7);
//! let err = parse("x").unwrap_err();
//! assert!(format!("{err:?}").contains("bad number"));
//! ```

use std::fmt;

/// An erased error with an outermost message and a cause chain.
pub struct Error {
    msg: String,
    /// Causes, outermost first.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), chain: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Error { msg: context.to_string(), chain }
    }

    /// The error chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.chain.iter().map(|s| s.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.chain.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does not implement `std::error::Error`; that
// is what makes this blanket conversion coherent (same as the real crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = Vec::new();
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { msg: e.to_string(), chain }
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`.
pub trait Context<T>: Sized {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error (or `None`) with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_shows_outermost_message_only() {
        let e: Error = io_err().into();
        let e = e.context("opening config");
        assert_eq!(format!("{e}"), "opening config");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error = io_err().into();
        let e = e.context("opening config");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("opening config"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("no such file"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(f().unwrap(), 12);
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        let e = anyhow!("bad value {}", 4);
        assert_eq!(e.to_string(), "bad value 4");
        let e = anyhow!(String::from("owned message"));
        assert_eq!(e.to_string(), "owned message");
    }

    #[test]
    fn bail_and_ensure_return_early() {
        fn f(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable for flag=true? no: always bails")
        }
        assert!(f(false).unwrap_err().to_string().contains("flag was false"));
        assert!(f(true).is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(5u32).context("missing").unwrap(), 5);
    }

    #[test]
    fn chain_iterates_outermost_first() {
        let e = Error::msg("inner").context("mid").context("outer");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "mid", "inner"]);
    }
}
